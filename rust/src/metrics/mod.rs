//! Serving metrics: named counters + log-bucketed histograms.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed histogram for positive values (latencies, batch sizes).
///
/// Buckets are `base * growth^i` boundaries covering [1e-7, ~1e4] seconds
/// with ~5% resolution -- good enough for p50/p99 on the serving path
/// without retaining samples.
#[derive(Debug)]
pub struct Histogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum_micros: AtomicU64,
}

const HIST_BUCKETS: usize = 512;
const HIST_MIN: f64 = 1e-7;
const HIST_GROWTH: f64 = 1.052;

fn bucket_of(v: f64) -> usize {
    if v <= HIST_MIN {
        return 0;
    }
    let idx = (v / HIST_MIN).ln() / HIST_GROWTH.ln();
    (idx as usize).min(HIST_BUCKETS - 1)
}

fn bucket_value(i: usize) -> f64 {
    HIST_MIN * HIST_GROWTH.powi(i as i32)
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, v: f64) {
        let v = v.max(0.0);
        self.counts[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_micros
            .fetch_add((v * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
    }

    /// Approximate quantile (within one bucket's ~5% resolution).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_value(i);
            }
        }
        bucket_value(HIST_BUCKETS - 1)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }
}

/// A registry of named counters and histograms.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Metrics {
    pub fn new() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.counters.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.histograms.lock().unwrap();
        Arc::clone(g.entry(name.to_string()).or_default())
    }

    /// Snapshot all metrics as display lines (name, value description).
    pub fn snapshot(&self) -> Vec<(String, String)> {
        let mut out = Vec::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push((name.clone(), format!("{}", c.get())));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            if h.count() > 0 {
                out.push((
                    name.clone(),
                    format!(
                        "n={} mean={:.6} p50={:.6} p99={:.6}",
                        h.count(),
                        h.mean(),
                        h.p50(),
                        h.p99()
                    ),
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let m = Metrics::new();
        m.counter("a").inc();
        m.counter("a").add(4);
        assert_eq!(m.counter("a").get(), 5);
        assert_eq!(m.counter("b").get(), 0);
    }

    #[test]
    fn histogram_quantiles_within_resolution() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // 1ms .. 1s uniform
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50();
        assert!((0.45..0.56).contains(&p50), "p50 {p50}");
        let p99 = h.p99();
        assert!((0.93..1.06).contains(&p99), "p99 {p99}");
        let mean = h.mean();
        assert!((0.48..0.53).contains(&mean), "mean {mean}");
    }

    #[test]
    fn histogram_empty_is_nan() {
        let h = Histogram::default();
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn histogram_extremes_clamped() {
        let h = Histogram::default();
        h.record(0.0);
        h.record(1e9);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) <= 2e-7);
        assert!(h.quantile(1.0) > 1e3);
    }

    #[test]
    fn snapshot_lists_everything() {
        let m = Metrics::new();
        m.counter("reqs").inc();
        m.histogram("lat").record(0.01);
        let snap = m.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"reqs"));
        assert!(names.contains(&"lat"));
    }

    #[test]
    fn concurrent_updates() {
        let m = Metrics::new();
        let c = m.counter("x");
        let hs = m.histogram("h");
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                let h = Arc::clone(&hs);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                        h.record(0.001);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
        assert_eq!(hs.count(), 8000);
    }
}
