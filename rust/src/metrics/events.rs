//! Controller/autoscaler event log: a timestamped, bounded ring of gear
//! shifts and replica scale actions for post-hoc analysis.
//!
//! Gauges answer "what is the system doing *now*"; the event log
//! answers "what did the controller decide, when, and why".  Every
//! entry records the decision's before/after (gear id, replica count),
//! which decider produced it (`gear` | `scale` | `budget` |
//! `admission`), the tier it acted on, the trigger that forced it
//! (`rate` | `pressure` | `slo` | `quota`) and -- for class-scoped
//! actions like quota sheds -- which SLO class it concerned.  The
//! log renders as JSONL (one JSON object per line) for the wire
//! `{"cmd":"events"}` command and `repro stats --events`, and can
//! optionally mirror every record into an append-only JSONL file
//! (`serve --events-file`).
//!
//! The ring is bounded ([`EVENT_CAPACITY`]) so a long-running server
//! cannot grow without bound; `dropped` counts evictions so readers
//! know the log is a suffix, not the full history.
//!
//! `record` never blocks on IO: the file sink is a
//! [`crate::obs::JsonlSink`], whose `append` only buffers in memory (a
//! background thread owns the disk writes), and even that append
//! happens AFTER the ring mutex is released.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::obs::JsonlSink;
use crate::util::json::{Json, JsonObj};

/// Max retained events; older entries are evicted (and counted).
pub const EVENT_CAPACITY: usize = 4096;

/// What a controller decision changed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Gear shift (ladder walk): `old_gear != new_gear`.
    Shift,
    /// Replica scale action: `old_replicas != new_replicas`.
    Scale,
    /// Admission rejection episode (e.g. a class hitting its
    /// weighted-fair quota, `trigger="quota"`): recorded once per
    /// pressure episode, not per shed request.
    Shed,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Shift => "shift",
            EventKind::Scale => "scale",
            EventKind::Shed => "shed",
        }
    }
}

/// What one decision changed, as handed to [`EventLog::record`] -- the
/// stamped [`Event`] adds `seq` and wall-clock time.  `decider` names
/// the stack member that produced the action ("gear" | "scale" |
/// "budget" when the arbiter clamped a grant | "drift" when a theta
/// was re-grounded) and `tier` is the unit index it acted on (0 for
/// monolithic pools), so shift and scale events attribute uniformly
/// across both serving layouts -- the tier index no longer rides in
/// the gear slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EventRecord {
    pub kind: EventKind,
    /// Decider that produced the action:
    /// "gear" | "scale" | "budget" | "drift".
    pub decider: &'static str,
    /// What forced the decision: "rate" | "pressure" | "slo" | "breach".
    pub trigger: &'static str,
    /// Unit/tier index the action applied to (0 for monolithic pools).
    pub tier: usize,
    pub old_gear: usize,
    pub new_gear: usize,
    pub old_replicas: usize,
    pub new_replicas: usize,
    /// SLO class the action concerned, when class-scoped (quota sheds,
    /// SLO-boost arbitration).  `None` -- the common case -- is OMITTED
    /// from the JSON/JSONL forms, so pre-class consumers parse
    /// unchanged.
    pub class: Option<&'static str>,
}

/// One recorded controller decision.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Monotone per-log sequence number (survives ring eviction).
    pub seq: u64,
    /// Wall-clock seconds since the UNIX epoch at record time.
    pub ts_s: f64,
    pub kind: EventKind,
    /// Decider that produced the action:
    /// "gear" | "scale" | "budget" | "drift".
    pub decider: &'static str,
    /// What forced the decision: "rate" | "pressure" | "slo" | "breach".
    pub trigger: &'static str,
    /// Unit/tier index the action applied to (0 for monolithic pools).
    pub tier: usize,
    pub old_gear: usize,
    pub new_gear: usize,
    pub old_replicas: usize,
    pub new_replicas: usize,
    /// See [`EventRecord::class`]; omitted from JSON when `None`.
    pub class: Option<&'static str>,
}

impl Event {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("seq", Json::num(self.seq as f64));
        o.insert("ts_s", Json::num(self.ts_s));
        o.insert("kind", Json::str(self.kind.name()));
        o.insert("decider", Json::str(self.decider));
        o.insert("trigger", Json::str(self.trigger));
        o.insert("tier", Json::num(self.tier as f64));
        o.insert("old_gear", Json::num(self.old_gear as f64));
        o.insert("new_gear", Json::num(self.new_gear as f64));
        o.insert("old_replicas", Json::num(self.old_replicas as f64));
        o.insert("new_replicas", Json::num(self.new_replicas as f64));
        if let Some(class) = self.class {
            o.insert("class", Json::str(class));
        }
        Json::Obj(o)
    }
}

struct LogState {
    ring: VecDeque<Event>,
    next_seq: u64,
    dropped: u64,
    sink: Option<JsonlSink>,
}

/// Bounded, thread-safe event ring + optional JSONL file sink.  One
/// lives in every [`crate::metrics::Metrics`] registry, so the pool,
/// the controller and the serving front end all share it without extra
/// plumbing.
pub struct EventLog {
    state: Mutex<LogState>,
}

impl std::fmt::Debug for EventLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.state.lock().unwrap();
        write!(f, "EventLog(len={}, dropped={})", s.ring.len(), s.dropped)
    }
}

impl Default for EventLog {
    fn default() -> Self {
        EventLog {
            state: Mutex::new(LogState {
                ring: VecDeque::new(),
                next_seq: 0,
                dropped: 0,
                sink: None,
            }),
        }
    }
}

impl EventLog {
    /// Record one decision; stamps `seq` + wall-clock time.  The file
    /// sink (when set) is appended to OUTSIDE the ring mutex, and the
    /// append itself is an in-memory buffer push -- recording never
    /// blocks on IO (best effort: sink IO errors never fail the
    /// control loop).
    pub fn record(&self, r: EventRecord) {
        let ts_s = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        let (event, sink) = {
            let mut s = self.state.lock().unwrap();
            let event = Event {
                seq: s.next_seq,
                ts_s,
                kind: r.kind,
                decider: r.decider,
                trigger: r.trigger,
                tier: r.tier,
                old_gear: r.old_gear,
                new_gear: r.new_gear,
                old_replicas: r.old_replicas,
                new_replicas: r.new_replicas,
                class: r.class,
            };
            s.next_seq += 1;
            if s.ring.len() >= EVENT_CAPACITY {
                s.ring.pop_front();
                s.dropped += 1;
            }
            s.ring.push_back(event.clone());
            (event, s.sink.clone())
        };
        if let Some(sink) = sink {
            sink.append(&event.to_json().to_string());
        }
    }

    /// Mirror every future record into `path` as append-only JSONL
    /// (buffered; a background thread flushes -- see
    /// [`EventLog::flush`]).
    pub fn set_file_sink(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let sink = JsonlSink::open(path)?;
        self.state.lock().unwrap().sink = Some(sink);
        Ok(())
    }

    /// Force the file sink's buffer (if any) to disk -- for shutdown
    /// and tests; steady-state flushing is the sink thread's job.
    pub fn flush(&self) {
        let sink = self.state.lock().unwrap().sink.clone();
        if let Some(sink) = sink {
            sink.flush();
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted from the ring (history truncated this many).
    pub fn dropped(&self) -> u64 {
        self.state.lock().unwrap().dropped
    }

    /// Copy of the retained events, oldest first.
    pub fn snapshot(&self) -> Vec<Event> {
        self.state.lock().unwrap().ring.iter().cloned().collect()
    }

    /// The retained events as a JSON array (wire `events` reply body).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.snapshot().iter().map(|e| e.to_json()).collect())
    }

    /// The retained events as JSONL text (one object per line).
    pub fn to_jsonl(&self) -> String {
        self.snapshot()
            .iter()
            .map(|e| e.to_json().to_string())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(kind: EventKind, trigger: &'static str) -> EventRecord {
        EventRecord {
            kind,
            decider: "gear",
            trigger,
            tier: 0,
            old_gear: 0,
            new_gear: 1,
            old_replicas: 2,
            new_replicas: 2,
            class: None,
        }
    }

    #[test]
    fn record_stamps_sequence_and_fields() {
        let log = EventLog::default();
        assert!(log.is_empty());
        log.record(rec(EventKind::Shift, "rate"));
        log.record(EventRecord {
            kind: EventKind::Scale,
            decider: "scale",
            trigger: "pressure",
            tier: 2,
            old_gear: 1,
            new_gear: 1,
            old_replicas: 2,
            new_replicas: 4,
            class: None,
        });
        let events = log.snapshot();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].seq, 0);
        assert_eq!(events[1].seq, 1);
        assert_eq!(events[0].kind, EventKind::Shift);
        assert_eq!(events[0].decider, "gear");
        assert_eq!(events[0].trigger, "rate");
        assert_eq!(events[0].tier, 0);
        assert_eq!(events[0].new_gear, 1);
        assert_eq!(events[1].kind, EventKind::Scale);
        assert_eq!(events[1].decider, "scale");
        assert_eq!(events[1].tier, 2);
        assert_eq!(events[1].old_replicas, 2);
        assert_eq!(events[1].new_replicas, 4);
        assert!(events[0].ts_s > 0.0);
        assert!(events[1].ts_s >= events[0].ts_s);
        assert_eq!(log.dropped(), 0);
    }

    #[test]
    fn json_and_jsonl_shapes() {
        let log = EventLog::default();
        log.record(EventRecord {
            kind: EventKind::Shift,
            decider: "gear",
            trigger: "slo",
            tier: 1,
            old_gear: 2,
            new_gear: 3,
            old_replicas: 1,
            new_replicas: 1,
            class: None,
        });
        let arr = log.to_json();
        let first = &arr.as_arr().unwrap()[0];
        assert_eq!(first.get("kind").as_str(), Some("shift"));
        assert_eq!(first.get("decider").as_str(), Some("gear"));
        assert_eq!(first.get("trigger").as_str(), Some("slo"));
        assert_eq!(first.get("tier").as_u64(), Some(1));
        assert_eq!(first.get("old_gear").as_u64(), Some(2));
        assert_eq!(first.get("new_gear").as_u64(), Some(3));
        // JSONL: one parseable object per line
        log.record(rec(EventKind::Scale, "rate"));
        let lines: Vec<&str> = log.to_jsonl().lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = Json::parse(line).unwrap();
            assert!(v.get("seq").as_u64().is_some());
            assert!(v.get("decider").as_str().is_some());
            assert!(v.get("tier").as_u64().is_some());
        }
    }

    #[test]
    fn class_field_is_omitted_when_absent() {
        let log = EventLog::default();
        log.record(rec(EventKind::Shift, "rate"));
        log.record(EventRecord {
            kind: EventKind::Shed,
            decider: "admission",
            trigger: "quota",
            class: Some("batch"),
            ..rec(EventKind::Shed, "quota")
        });
        let lines: Vec<String> =
            log.to_jsonl().lines().map(|l| l.to_string()).collect();
        // Untagged event: no "class" key at all (pre-class consumers
        // parse unchanged).
        assert!(!lines[0].contains("\"class\""));
        let shed = Json::parse(&lines[1]).unwrap();
        assert_eq!(shed.get("kind").as_str(), Some("shed"));
        assert_eq!(shed.get("decider").as_str(), Some("admission"));
        assert_eq!(shed.get("trigger").as_str(), Some("quota"));
        assert_eq!(shed.get("class").as_str(), Some("batch"));
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let log = EventLog::default();
        for i in 0..(EVENT_CAPACITY + 10) {
            log.record(EventRecord {
                old_replicas: i,
                new_replicas: i + 1,
                ..rec(EventKind::Scale, "rate")
            });
        }
        assert_eq!(log.len(), EVENT_CAPACITY);
        assert_eq!(log.dropped(), 10);
        let events = log.snapshot();
        // suffix survives: oldest retained is seq 10
        assert_eq!(events[0].seq, 10);
        assert_eq!(events.last().unwrap().seq, (EVENT_CAPACITY + 10 - 1) as u64);
    }

    #[test]
    fn file_sink_appends_jsonl() {
        let dir = std::env::temp_dir().join(format!("abc-ev-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let log = EventLog::default();
        log.set_file_sink(&path).unwrap();
        log.record(rec(EventKind::Shift, "rate"));
        log.record(EventRecord {
            new_replicas: 3,
            ..rec(EventKind::Scale, "rate")
        });
        // record() only buffers; force the sink to disk before reading
        log.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            Json::parse(lines[1]).unwrap().get("new_replicas").as_u64(),
            Some(3)
        );
    }
}
