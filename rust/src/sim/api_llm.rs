//! Simulated black-box LLM API endpoints (paper §5.2.3 substrate).
//!
//! We cannot call together.ai from this testbed, so we simulate the
//! *interface* the routing policies see: `answer(prompt) -> (answer,
//! tokens)` billed per token at Table 1 prices.  Each simulated model has
//! a logistic accuracy-vs-difficulty curve calibrated so tier accuracies
//! match the paper's tiers (8B-class ~ 65-75%, 70B-class ~ 80-88%,
//! 405B ~ 88-93%, task-dependent), and a per-task token-count
//! distribution (4-shot prompts; GSM8K's chain-of-thought answers are
//! long, OVERRULING's yes/no short).
//!
//! Error correlation matters for voting: wrong models don't pick wrong
//! answers independently -- plausible distractors attract everyone.  Each
//! sample carries a shared distractor ranking; a wrong model picks the
//! top distractor with probability `distractor_pull`, else a random one.
//! This keeps ensemble agreement informative but imperfect, which is the
//! regime ABC actually operates in (DESIGN.md substitution table).

use crate::cost::api::{call_cost, ApiModel};
use crate::util::rng::Rng;

/// A generation task suite (stand-in for GSM8K / CoQA / OVERRULING /
/// HEADLINES -- closed answer spaces per the paper's evaluation setup).
#[derive(Debug, Clone)]
pub struct LlmTask {
    pub name: &'static str,
    pub paper_dataset: &'static str,
    /// Size of the (closed) answer space.
    pub answer_space: usize,
    pub n_samples: usize,
    /// Difficulty Beta(a, b).
    pub diff_a: f64,
    pub diff_b: f64,
    /// Mean tokens per call (4-shot prompt + completion).
    pub tokens_mean: f64,
    pub tokens_std: f64,
    /// Per-tier base accuracy at mean difficulty, tiers 1..=3.
    pub tier_base_acc: [f64; 3],
    /// Chance a wrong answer lands on the sample's top shared distractor.
    /// High for small answer spaces (plausible wrong answers coincide),
    /// low for open numeric spaces like GSM8K where wrong chains of
    /// thought rarely produce the same wrong number.
    pub distractor_pull: f64,
    /// Accuracy drop from difficulty (logistic slope).
    pub diff_slope: f64,
    pub seed: u64,
}

/// The four black-box tasks of Table 2.
pub fn default_tasks() -> Vec<LlmTask> {
    vec![
        LlmTask {
            name: "synth-gsm8k",
            paper_dataset: "GSM8K",
            answer_space: 1000,
            n_samples: 1000,
            diff_a: 2.2,
            diff_b: 2.2,
            tokens_mean: 620.0,
            tokens_std: 140.0,
            tier_base_acc: [0.84, 0.94, 0.97],
            distractor_pull: 0.18,
            diff_slope: 3.2,
            seed: 7101,
        },
        LlmTask {
            name: "synth-coqa",
            paper_dataset: "CoQA",
            answer_space: 48,
            n_samples: 1000,
            diff_a: 1.5,
            diff_b: 3.0,
            tokens_mean: 380.0,
            tokens_std: 90.0,
            tier_base_acc: [0.90, 0.96, 0.98],
            distractor_pull: 0.35,
            diff_slope: 4.0,
            seed: 7102,
        },
        LlmTask {
            name: "synth-overruling",
            paper_dataset: "OVERRULING",
            answer_space: 2,
            n_samples: 800,
            diff_a: 1.2,
            diff_b: 3.5,
            tokens_mean: 210.0,
            tokens_std: 40.0,
            tier_base_acc: [0.955, 0.985, 0.99],
            distractor_pull: 0.5,
            diff_slope: 3.2,
            seed: 7103,
        },
        LlmTask {
            name: "synth-headlines",
            paper_dataset: "HEADLINES",
            answer_space: 4,
            n_samples: 1000,
            diff_a: 1.3,
            diff_b: 3.2,
            tokens_mean: 150.0,
            tokens_std: 30.0,
            tier_base_acc: [0.92, 0.97, 0.985],
            distractor_pull: 0.45,
            diff_slope: 3.5,
            seed: 7104,
        },
    ]
}

/// One test sample.
#[derive(Debug, Clone)]
pub struct LlmSample {
    pub id: usize,
    pub truth: u32,
    pub difficulty: f64,
    /// Shared distractor ranking (the "plausible wrong answers").
    pub distractors: Vec<u32>,
}

/// Generate the deterministic sample set of a task.
pub fn generate_samples(task: &LlmTask) -> Vec<LlmSample> {
    let mut rng = Rng::new(task.seed);
    (0..task.n_samples)
        .map(|id| {
            let truth = rng.below(task.answer_space) as u32;
            let difficulty = rng.beta(task.diff_a, task.diff_b);
            let n_distract = 3.min(task.answer_space - 1);
            let mut distractors = Vec::with_capacity(n_distract);
            while distractors.len() < n_distract {
                let d = rng.below(task.answer_space) as u32;
                if d != truth && !distractors.contains(&d) {
                    distractors.push(d);
                }
            }
            LlmSample { id, truth, difficulty, distractors }
        })
        .collect()
}

/// A simulated hosted model.
#[derive(Debug, Clone)]
pub struct LlmAgent {
    pub model: ApiModel,
    /// Accuracy on a MEAN-difficulty sample of the task.
    pub base_acc: f64,
    pub diff_slope: f64,
    /// The task's mean difficulty (the logistic's centre).
    pub mean_difficulty: f64,
    /// Chance a wrong answer is the sample's top shared distractor.
    pub distractor_pull: f64,
    /// Small per-model skill jitter so same-tier models differ.
    pub skill_delta: f64,
}

impl LlmAgent {
    /// P(correct | difficulty) -- logistic in difficulty, centred at the
    /// task's mean difficulty so `base_acc` IS the expected accuracy
    /// (up to Jensen's inequality).
    pub fn p_correct(&self, difficulty: f64) -> f64 {
        let logit_base = logit(self.base_acc.clamp(1e-4, 1.0 - 1e-4)) + self.skill_delta;
        sigmoid(logit_base - self.diff_slope * (difficulty - self.mean_difficulty))
    }

    /// One API call: returns (answer, billed tokens).
    ///
    /// `temperature` widens the answer distribution: at temp 0 the model
    /// deterministically answers its modal answer for the sample; higher
    /// temps re-sample correctness and distractor choice independently
    /// (the MoT/AutoMix sampling knob).
    pub fn answer(
        &self,
        sample: &LlmSample,
        temperature: f64,
        task: &LlmTask,
        rng: &mut Rng,
    ) -> (u32, u64) {
        // Deterministic per-(model, sample) stream for the temp-0 modal
        // answer; temperature mixes in call-level randomness.
        let mut modal_rng = Rng::new(
            (sample.id as u64)
                .wrapping_mul(0x9E3779B97F4A7C15)
                ^ hash_name(self.model.name),
        );
        // Random-effects correctness model: the marginal P(correct) is
        // p_correct(difficulty), but each (model, sample) pair carries a
        // SYSTEMATIC shift eta -- the model either "gets" this problem or
        // it doesn't.  Temp-0 answers are the modal draw; temp>0 draws
        // are iid Bernoulli(p_eff) given eta, so MoT-style
        // self-consistency amplifies p_eff toward 0 or 1 (consistently
        // wrong stays wrong) instead of washing errors out.
        let p = self.p_correct(sample.difficulty);
        let eta = 1.2 * modal_rng.normal();
        let p_eff = sigmoid(logit(p.clamp(1e-4, 1.0 - 1e-4)) + eta);
        let draw = if temperature <= 0.0 { modal_rng.f64() } else { rng.f64() };
        let answer = if draw < p_eff {
            sample.truth
        } else {
            // wrong: pulled toward the shared distractor
            let pick_rng: &mut Rng =
                if temperature <= 0.0 { &mut modal_rng } else { &mut *rng };
            if !sample.distractors.is_empty() && pick_rng.bool(self.distractor_pull) {
                sample.distractors[0]
            } else if !sample.distractors.is_empty() {
                sample.distractors[pick_rng.below(sample.distractors.len())]
            } else {
                // binary task: the only wrong answer
                (1 - sample.truth.min(1)) as u32
            }
        };
        let tokens = (task.tokens_mean + task.tokens_std * rng.normal())
            .max(20.0) as u64;
        (answer, tokens)
    }

    /// Dollar cost of a call with `tokens` tokens.
    pub fn cost(&self, tokens: u64) -> f64 {
        call_cost(&self.model, tokens)
    }
}

/// Build the Table 1 agent fleet for a task: 3 tier-1 agents, 3 tier-2
/// agents, 1 tier-3 agent, accuracy-calibrated to the task.
pub fn build_agents(task: &LlmTask) -> Vec<LlmAgent> {
    let mut agents = Vec::new();
    for m in crate::cost::api::table1_models() {
        let base = task.tier_base_acc[m.tier - 1];
        // same-tier models differ a little; cheaper model in tier = a bit weaker
        let skill_delta = match m.name {
            "LlaMA 3 8B Instruct Lite" => -0.25,
            "Gemma 2 9B IT" => 0.10,
            "Gemma 2 27B Instruct" => -0.10,
            "Qwen 2 72B-Instruct" => 0.05,
            _ => 0.0,
        };
        agents.push(LlmAgent {
            model: m,
            base_acc: base,
            diff_slope: task.diff_slope,
            mean_difficulty: task.diff_a / (task.diff_a + task.diff_b),
            distractor_pull: task.distractor_pull,
            skill_delta,
        });
    }
    agents
}

/// Agents of one tier.
pub fn tier_agents(agents: &[LlmAgent], tier: usize) -> Vec<&LlmAgent> {
    agents.iter().filter(|a| a.model.tier == tier).collect()
}

/// The best single agent of a tier (highest effective accuracy) -- the
/// paper gives the single-model baselines the best model per tier.
pub fn best_of_tier(agents: &[LlmAgent], tier: usize) -> &LlmAgent {
    tier_agents(agents, tier)
        .into_iter()
        .max_by(|a, b| {
            a.p_correct(0.3)
                .partial_cmp(&b.p_correct(0.3))
                .unwrap()
        })
        .expect("tier has agents")
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

fn logit(p: f64) -> f64 {
    (p / (1.0 - p)).ln()
}

fn hash_name(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> LlmTask {
        default_tasks().remove(0)
    }

    #[test]
    fn samples_deterministic() {
        let t = task();
        let a = generate_samples(&t);
        let b = generate_samples(&t);
        assert_eq!(a.len(), t.n_samples);
        assert_eq!(a[17].truth, b[17].truth);
        assert_eq!(a[17].distractors, b[17].distractors);
        assert!(a.iter().all(|s| !s.distractors.contains(&s.truth)));
    }

    #[test]
    fn accuracy_ladder_is_monotone() {
        let t = task();
        let samples = generate_samples(&t);
        let agents = build_agents(&t);
        let mut rng = Rng::new(1);
        let mut accs = Vec::new();
        for tier in 1..=3 {
            let agent = best_of_tier(&agents, tier);
            let hits = samples
                .iter()
                .filter(|s| agent.answer(s, 0.0, &t, &mut rng).0 == s.truth)
                .count();
            accs.push(hits as f64 / samples.len() as f64);
        }
        assert!(accs[0] < accs[1] && accs[1] < accs[2], "ladder {accs:?}");
        assert!(accs[0] > 0.5, "tier1 sane: {accs:?}");
        assert!(accs[2] > 0.85, "tier3 strong: {accs:?}");
    }

    #[test]
    fn temp0_is_deterministic_per_model_sample() {
        let t = task();
        let samples = generate_samples(&t);
        let agents = build_agents(&t);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(999);
        let (a1, _) = agents[0].answer(&samples[5], 0.0, &t, &mut r1);
        let (a2, _) = agents[0].answer(&samples[5], 0.0, &t, &mut r2);
        assert_eq!(a1, a2, "temp-0 answers are modal");
    }

    #[test]
    fn same_tier_models_disagree_sometimes() {
        let t = task();
        let samples = generate_samples(&t);
        let agents = build_agents(&t);
        let t1 = tier_agents(&agents, 1);
        let mut rng = Rng::new(2);
        let mut disagreements = 0;
        for s in &samples {
            let answers: Vec<u32> =
                t1.iter().map(|a| a.answer(s, 0.0, &t, &mut rng).0).collect();
            if answers.iter().any(|&x| x != answers[0]) {
                disagreements += 1;
            }
        }
        let frac = disagreements as f64 / samples.len() as f64;
        assert!(frac > 0.05 && frac < 0.8, "disagreement rate {frac}");
    }

    #[test]
    fn disagreement_concentrates_on_hard_samples() {
        let t = task();
        let samples = generate_samples(&t);
        let agents = build_agents(&t);
        let t1 = tier_agents(&agents, 1);
        let mut rng = Rng::new(3);
        let (mut dis_easy, mut n_easy, mut dis_hard, mut n_hard) = (0, 0, 0, 0);
        for s in &samples {
            let answers: Vec<u32> =
                t1.iter().map(|a| a.answer(s, 0.0, &t, &mut rng).0).collect();
            let dis = answers.iter().any(|&x| x != answers[0]) as u32;
            if s.difficulty < 0.3 {
                dis_easy += dis;
                n_easy += 1;
            } else if s.difficulty > 0.7 {
                dis_hard += dis;
                n_hard += 1;
            }
        }
        let easy = dis_easy as f64 / n_easy.max(1) as f64;
        let hard = dis_hard as f64 / n_hard.max(1) as f64;
        assert!(hard > easy + 0.2, "easy {easy} vs hard {hard}");
    }

    #[test]
    fn tokens_billed_positive_and_priced() {
        let t = task();
        let samples = generate_samples(&t);
        let agents = build_agents(&t);
        let mut rng = Rng::new(4);
        let (_, tokens) = agents[6].answer(&samples[0], 0.0, &t, &mut rng);
        assert!(tokens >= 20);
        let cost = agents[6].cost(tokens);
        assert!(cost > 0.0);
        // 405B at $5/Mtok: ~620 tokens ~ $0.003
        assert!(cost < 0.02);
    }

    #[test]
    fn temperature_adds_variance() {
        let t = task();
        let samples = generate_samples(&t);
        let agents = build_agents(&t);
        let mut rng = Rng::new(5);
        // find a hard sample where temp-1 answers vary across calls
        let mut varied = false;
        for s in samples.iter().filter(|s| s.difficulty > 0.6).take(30) {
            let answers: Vec<u32> =
                (0..8).map(|_| agents[0].answer(s, 1.0, &t, &mut rng).0).collect();
            if answers.iter().any(|&x| x != answers[0]) {
                varied = true;
                break;
            }
        }
        assert!(varied, "temperature should induce answer variance");
    }
}
