//! Discrete-event edge-to-cloud serving simulator (paper §5.2.1
//! substrate, queueing-aware version of the analytic cost/comm model).
//!
//! The analytic model in `cost::comm` prices each request by its exit
//! point; this simulator additionally models *contention*: the edge
//! device is a single-server queue (a phone runs one ensemble at a
//! time), the cloud a many-server queue, and the uplink adds the delay
//! class.  It answers the deployment question the paper's §5.2.1 poses
//! -- when does keeping traffic on the edge also help latency under
//! load? -- and feeds the `edge_sim` ablation experiment.

use crate::util::rng::Rng;
use crate::util::stats::Samples;

/// Simulator parameters.
#[derive(Debug, Clone)]
pub struct EdgeCloudParams {
    /// Mean edge (tier-1 ensemble) service time per request (s).
    pub edge_service_s: f64,
    /// Mean cloud (top tier) service time per request (s).
    pub cloud_service_s: f64,
    /// One-way uplink delay (the paper's delay classes) (s).
    pub uplink_s: f64,
    /// Number of parallel cloud servers.
    pub cloud_servers: usize,
    /// Fraction of requests the edge tier answers locally (exit frac).
    pub edge_exit_frac: f64,
    /// Request rate (req/s), Poisson arrivals.
    pub rate: f64,
    pub n_requests: usize,
    pub seed: u64,
}

/// Aggregate simulation outcome.
#[derive(Debug, Clone)]
pub struct EdgeCloudReport {
    pub mean_latency_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    /// Mean time spent in queues (edge + cloud).
    pub mean_queueing_s: f64,
    /// Fraction answered at the edge.
    pub edge_fraction: f64,
    /// Edge server utilisation.
    pub edge_utilisation: f64,
}

/// Simulate the ABC placement: every request runs the edge ensemble
/// (single server, FIFO); deferred requests then cross the uplink and
/// run on the cloud (c servers, FIFO).
pub fn simulate_abc(p: &EdgeCloudParams) -> EdgeCloudReport {
    let mut rng = Rng::new(p.seed);
    let mut lat = Samples::new();
    let mut queueing = Samples::new();
    let mut edge_free_at = 0.0f64; // single edge server
    let mut cloud_free_at = vec![0.0f64; p.cloud_servers.max(1)];
    let mut edge_busy = 0.0;
    let mut t_arrive = 0.0;
    let mut edge_answered = 0usize;
    for _ in 0..p.n_requests {
        t_arrive += rng.exp(p.rate);
        // --- edge stage (always runs: the deferral rule needs tier 1)
        let edge_start = t_arrive.max(edge_free_at);
        let edge_service = rng.exp(1.0 / p.edge_service_s.max(1e-12));
        let edge_done = edge_start + edge_service;
        edge_free_at = edge_done;
        edge_busy += edge_service;
        let mut wait = edge_start - t_arrive;
        let done = if rng.bool(p.edge_exit_frac) {
            edge_answered += 1;
            edge_done
        } else {
            // --- defer: uplink, then cloud queue (earliest-free server)
            let at_cloud = edge_done + p.uplink_s;
            let (srv_idx, _) = cloud_free_at
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            let start = at_cloud.max(cloud_free_at[srv_idx]);
            wait += start - at_cloud;
            let service = rng.exp(1.0 / p.cloud_service_s.max(1e-12));
            cloud_free_at[srv_idx] = start + service;
            start + service
        };
        lat.push(done - t_arrive);
        queueing.push(wait);
    }
    let horizon = t_arrive.max(1e-9);
    EdgeCloudReport {
        mean_latency_s: lat.mean(),
        p50_s: lat.p50(),
        p99_s: lat.p99(),
        mean_queueing_s: queueing.mean(),
        edge_fraction: edge_answered as f64 / p.n_requests as f64,
        edge_utilisation: (edge_busy / horizon).min(1.0),
    }
}

/// Simulate the cloud-only baseline: every request crosses the uplink
/// and runs on the cloud fleet.
pub fn simulate_cloud_only(p: &EdgeCloudParams) -> EdgeCloudReport {
    let mut rng = Rng::new(p.seed ^ 0x5151);
    let mut lat = Samples::new();
    let mut queueing = Samples::new();
    let mut cloud_free_at = vec![0.0f64; p.cloud_servers.max(1)];
    let mut t_arrive = 0.0;
    for _ in 0..p.n_requests {
        t_arrive += rng.exp(p.rate);
        let at_cloud = t_arrive + p.uplink_s;
        let (srv_idx, _) = cloud_free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = at_cloud.max(cloud_free_at[srv_idx]);
        let service = rng.exp(1.0 / p.cloud_service_s.max(1e-12));
        cloud_free_at[srv_idx] = start + service;
        lat.push(start + service - t_arrive);
        queueing.push(start - at_cloud);
    }
    EdgeCloudReport {
        mean_latency_s: lat.mean(),
        p50_s: lat.p50(),
        p99_s: lat.p99(),
        mean_queueing_s: queueing.mean(),
        edge_fraction: 0.0,
        edge_utilisation: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> EdgeCloudParams {
        EdgeCloudParams {
            edge_service_s: 0.002,
            cloud_service_s: 0.004,
            uplink_s: 0.100,
            cloud_servers: 8,
            edge_exit_frac: 0.8,
            rate: 50.0,
            n_requests: 20_000,
            seed: 1,
        }
    }

    #[test]
    fn abc_beats_cloud_only_at_high_edge_exit() {
        let p = base();
        let abc = simulate_abc(&p);
        let cloud = simulate_cloud_only(&p);
        // 80% of requests skip the 100ms uplink entirely
        assert!(abc.mean_latency_s < cloud.mean_latency_s / 3.0,
            "abc {} vs cloud {}", abc.mean_latency_s, cloud.mean_latency_s);
        assert!((abc.edge_fraction - 0.8).abs() < 0.02);
    }

    #[test]
    fn cloud_only_latency_is_uplink_dominated() {
        let p = base();
        let cloud = simulate_cloud_only(&p);
        assert!(cloud.mean_latency_s >= p.uplink_s);
        assert!(cloud.mean_latency_s < p.uplink_s + 0.05);
    }

    #[test]
    fn edge_saturation_degrades_abc() {
        // push the single edge server past capacity: 1/0.002 = 500 rps max
        let mut p = base();
        p.rate = 600.0;
        p.n_requests = 5_000;
        let sat = simulate_abc(&p);
        p.rate = 50.0;
        let calm = simulate_abc(&p);
        assert!(sat.mean_latency_s > 5.0 * calm.mean_latency_s,
            "saturated {} vs calm {}", sat.mean_latency_s, calm.mean_latency_s);
        assert!(sat.edge_utilisation > 0.95);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = base();
        let a = simulate_abc(&p);
        let b = simulate_abc(&p);
        assert_eq!(a.mean_latency_s, b.mean_latency_s);
        assert_eq!(a.p99_s, b.p99_s);
    }

    #[test]
    fn zero_exit_fraction_worse_than_cloud_only() {
        // edge tier that never answers = pure overhead
        let mut p = base();
        p.edge_exit_frac = 0.0;
        let abc = simulate_abc(&p);
        let cloud = simulate_cloud_only(&p);
        assert!(abc.mean_latency_s >= cloud.mean_latency_s * 0.95);
    }

    #[test]
    fn utilisation_scales_with_rate() {
        let mut p = base();
        p.rate = 25.0;
        let lo = simulate_abc(&p);
        p.rate = 250.0;
        let hi = simulate_abc(&p);
        assert!(hi.edge_utilisation > 2.0 * lo.edge_utilisation);
    }
}
