//! Deployment-scenario simulators: edge-to-cloud networking and the
//! black-box LLM API fleet (DESIGN.md substitution table).

pub mod api_llm;
pub mod edge_cloud;
