//! SLO observatory: per-class (tenant) telemetry and error-budget
//! burn-rate alarms.
//!
//! Aggregate fleet gauges hide who is being hurt: one bursty tenant's
//! shedding and latency are invisible inside a fleet-wide p99.  The
//! observatory keeps, per [`Class`], exactly-once books
//! (`class_{c}_submitted == class_{c}_completed + class_{c}_shed`,
//! summing to the fleet identity), a latency histogram read through
//! *windowed* snapshots (so past overloads cannot latch the published
//! p99), attainment/goodput gauges, and a two-window **error-budget
//! burn-rate alarm** per class -- the classic fast/slow pairing: the
//! fast window catches a cliff in minutes of damage, the slow window
//! refuses to page on a blip, and both must agree before the raw
//! verdict says Breach.  Raw verdicts feed the same hysteresis machine
//! as the drift observatory ([`DriftAlarm`]), so one unlucky window
//! cannot flap ok -> breach -> ok.
//!
//! Hot-path discipline (DESIGN.md §12): the `record_*` methods touch
//! only pre-resolved counter/histogram handles -- striped atomics, no
//! registry map locks, no allocation.  All windowed math lives behind
//! ONE mutex ([`SloObservatory::state`], the single textual lock
//! acquisition in this file, frozen in
//! `scripts/hotpath_lock_baseline.txt`), touched only by `refresh` /
//! `tick` (gauge publication), `status` and the wire `{"cmd":"slo"}`
//! reader -- never per request.
//!
//! Gauges (`class_{c}_p99_s`, `class_{c}_goodput_rps`,
//! `class_{c}_slo_attainment`, `class_{c}_slo_alarm`) are registered
//! *lazily*, on the first refresh that sees traffic for the class: a
//! class that never appears leaves no zero-value series in
//! `render_prom` / `snapshot_json` (the elided-when-empty contract the
//! drift gauges also follow).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::metrics::{Counter, Gauge, Histogram, Metrics};
use crate::obs::drift::{AlarmState, DriftAlarm};
use crate::types::Class;
use crate::util::json::{Json, JsonObj};

/// Per-class SLO targets and burn-alarm windows.
#[derive(Debug, Clone, Copy)]
pub struct SloConfig {
    /// Latency SLO per class, indexed by [`Class::index`]: a completed
    /// request is in-SLO iff `latency_s <= targets_s[class]`.  Shed
    /// requests are always misses -- a tenant does not care whether the
    /// deadline died in a queue or at the door.
    pub targets_s: [f64; Class::COUNT],
    /// Attainment goal (e.g. 0.95); the error budget is `1 - goal`.
    pub goal: f64,
    /// Fast burn window in seconds (catches cliffs).
    pub fast_window_s: f64,
    /// Slow burn window in seconds (refuses blips); also bounds the
    /// sample ring.
    pub slow_window_s: f64,
    /// Both windows must burn at or above this multiple of budget for a
    /// raw Breach verdict; the slow window alone above 1.0 is Warn.
    pub breach_mult: f64,
    /// Consecutive same-candidate raw verdicts before the published
    /// alarm moves (the [`DriftAlarm`] streak).
    pub hysteresis: usize,
    /// Below this many requests (completed + shed) in the slow window
    /// the raw verdict is Ok -- thin evidence never pages.
    pub min_requests: u64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            targets_s: [0.05, 0.25, 2.0],
            goal: 0.95,
            fast_window_s: 5.0,
            slow_window_s: 30.0,
            breach_mult: 2.0,
            hysteresis: 3,
            min_requests: 50,
        }
    }
}

/// One class's published picture (counters are cumulative; `p99_s`,
/// `goodput_rps` and the burns are from the most recent window).
#[derive(Debug, Clone, Copy)]
pub struct SloStatus {
    pub class: Class,
    pub target_s: f64,
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub deferred: u64,
    pub in_slo: u64,
    /// Cumulative attainment `in_slo / (completed + shed)`; NaN before
    /// the class has finished any request.
    pub attainment: f64,
    /// Windowed p99 (NaN when the last window held no completions).
    pub p99_s: f64,
    /// Completions per second over the last window.
    pub goodput_rps: f64,
    /// Budget-burn multiple over the fast window (1.0 = exactly on
    /// budget).
    pub fast_burn: f64,
    /// Budget-burn multiple over the slow window.
    pub slow_burn: f64,
    /// Published (hysteresis-latched) alarm state.
    pub alarm: AlarmState,
}

/// Pre-resolved hot-path handles for one class.
struct ClassHandles {
    submitted: Arc<Counter>,
    completed: Arc<Counter>,
    shed: Arc<Counter>,
    deferred: Arc<Counter>,
    in_slo: Arc<Counter>,
    latency: Arc<Histogram>,
}

/// Lazily-registered gauges for one class (absent until the class has
/// traffic, so idle classes publish no series).
struct ClassGauges {
    p99: Arc<Gauge>,
    goodput: Arc<Gauge>,
    attainment: Arc<Gauge>,
    alarm: Arc<Gauge>,
}

/// One refresh interval's worth of evidence.
struct BurnSample {
    dt_s: f64,
    /// Requests that reached a terminal fate (completed + shed).
    events: u64,
    /// Terminal requests that missed the SLO (late or shed).
    misses: u64,
}

struct ClassWindow {
    prev_hist: Vec<u64>,
    prev_completed: u64,
    prev_in_slo: u64,
    prev_shed: u64,
    ring: VecDeque<BurnSample>,
    alarm: DriftAlarm,
    gauges: Option<ClassGauges>,
    p99_s: f64,
    goodput_rps: f64,
    fast_burn: f64,
    slow_burn: f64,
}

struct SloState {
    classes: Vec<ClassWindow>,
    last_refresh: Instant,
}

/// Per-class SLO telemetry; see the module docs.  One lives in the
/// serving backend's top-level registry (the fleet registry for a
/// [`crate::coordinator::router::TieredFleet`], the pool registry for a
/// monolithic [`crate::coordinator::replica::ReplicaPool`]) so the
/// per-class series ride the existing `stats` / `prom` surfaces.
pub struct SloObservatory {
    cfg: SloConfig,
    handles: Vec<ClassHandles>,
    metrics: Arc<Metrics>,
    state: Mutex<SloState>,
}

impl std::fmt::Debug for SloObservatory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SloObservatory(goal={})", self.cfg.goal)
    }
}

/// Minimum wall-clock interval between two `refresh` ticks: several
/// surfaces (fleet gauge refresh, the wire command, the control loop's
/// publish) may call `refresh` back to back, and a near-zero window
/// would feed the burn ring degenerate samples.
const MIN_REFRESH_DT_S: f64 = 0.05;

impl SloObservatory {
    /// Build the observatory and pre-resolve every per-class counter
    /// and histogram into `metrics` (`class_{c}_submitted` etc.), once.
    pub fn new(cfg: SloConfig, metrics: &Arc<Metrics>) -> Arc<SloObservatory> {
        let handles = Class::ALL
            .iter()
            .map(|c| {
                let n = c.name();
                ClassHandles {
                    submitted: metrics.counter(&format!("class_{n}_submitted")),
                    completed: metrics.counter(&format!("class_{n}_completed")),
                    shed: metrics.counter(&format!("class_{n}_shed")),
                    deferred: metrics.counter(&format!("class_{n}_deferred")),
                    in_slo: metrics.counter(&format!("class_{n}_in_slo")),
                    latency: metrics.histogram(&format!("class_{n}_latency_s")),
                }
            })
            .collect();
        let classes = Class::ALL
            .iter()
            .map(|_| ClassWindow {
                prev_hist: Vec::new(),
                prev_completed: 0,
                prev_in_slo: 0,
                prev_shed: 0,
                ring: VecDeque::new(),
                alarm: DriftAlarm::new(cfg.hysteresis),
                gauges: None,
                p99_s: f64::NAN,
                goodput_rps: 0.0,
                fast_burn: 0.0,
                slow_burn: 0.0,
            })
            .collect();
        Arc::new(SloObservatory {
            cfg,
            handles,
            metrics: Arc::clone(metrics),
            state: Mutex::new(SloState {
                classes,
                last_refresh: Instant::now(),
            }),
        })
    }

    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// The ONLY lock acquisition in this file: every windowed-state
    /// path funnels through here (see the module docs' lock budget).
    fn state(&self) -> MutexGuard<'_, SloState> {
        self.state.lock().unwrap()
    }

    // ----- hot path (atomics only) -------------------------------------

    pub fn record_submitted(&self, class: Class) {
        self.handles[class.index()].submitted.inc();
    }

    /// Record a completion; the in-SLO judgement happens here, on the
    /// hot path, so the windowed attainment needs no latency replay.
    pub fn record_completed(&self, class: Class, latency_s: f64) {
        let h = &self.handles[class.index()];
        h.completed.inc();
        h.latency.record(latency_s);
        if latency_s <= self.cfg.targets_s[class.index()] {
            h.in_slo.inc();
        }
    }

    pub fn record_shed(&self, class: Class) {
        self.handles[class.index()].shed.inc();
    }

    pub fn record_deferred(&self, class: Class) {
        self.handles[class.index()].deferred.inc();
    }

    // ----- windowed refresh (off the hot path) -------------------------

    /// Wall-clock tick: advance the windows by the time elapsed since
    /// the previous refresh.  No-ops when called again within
    /// [`MIN_REFRESH_DT_S`], so stacked surfaces cannot shred the ring.
    pub fn refresh(&self) {
        let dt_s = {
            let st = self.state();
            st.last_refresh.elapsed().as_secs_f64()
        };
        if dt_s < MIN_REFRESH_DT_S {
            return;
        }
        self.tick(dt_s);
    }

    /// Deterministic tick: fold the counter deltas since the last tick
    /// into one `dt_s`-second burn sample per class, re-derive the
    /// windowed p99/goodput/burns, step the alarms and publish gauges.
    /// Tests drive this directly with synthetic dt.
    pub fn tick(&self, dt_s: f64) {
        let dt_s = dt_s.max(1e-9);
        let mut st = self.state();
        st.last_refresh = Instant::now();
        for (i, class) in Class::ALL.iter().enumerate() {
            let h = &self.handles[i];
            let submitted = h.submitted.get();
            let completed = h.completed.get();
            let in_slo = h.in_slo.get();
            let shed = h.shed.get();
            let cur_hist = h.latency.bucket_snapshot();
            let w = &mut st.classes[i];

            let d_completed = completed.saturating_sub(w.prev_completed);
            let d_in_slo = in_slo.saturating_sub(w.prev_in_slo);
            let d_shed = shed.saturating_sub(w.prev_shed);
            let events = d_completed + d_shed;
            let misses = events.saturating_sub(d_in_slo);

            w.p99_s = if w.prev_hist.is_empty() {
                Histogram::quantile_between(&vec![0; cur_hist.len()], &cur_hist, 0.99)
            } else {
                Histogram::quantile_between(&w.prev_hist, &cur_hist, 0.99)
            };
            w.goodput_rps = d_completed as f64 / dt_s;
            w.prev_hist = cur_hist;
            w.prev_completed = completed;
            w.prev_in_slo = in_slo;
            w.prev_shed = shed;

            w.ring.push_back(BurnSample { dt_s, events, misses });
            // keep at most slow_window_s of history (always at least
            // the newest sample)
            let mut span: f64 = w.ring.iter().map(|s| s.dt_s).sum();
            while w.ring.len() > 1
                && span - w.ring.front().map(|s| s.dt_s).unwrap_or(0.0)
                    >= self.cfg.slow_window_s
            {
                span -= w.ring.pop_front().map(|s| s.dt_s).unwrap_or(0.0);
            }

            let budget = (1.0 - self.cfg.goal).max(1e-9);
            let burn_over = |window_s: f64| -> (u64, f64) {
                let mut acc_dt = 0.0;
                let mut ev = 0u64;
                let mut miss = 0u64;
                for s in w.ring.iter().rev() {
                    if acc_dt >= window_s {
                        break;
                    }
                    acc_dt += s.dt_s;
                    ev += s.events;
                    miss += s.misses;
                }
                if ev == 0 {
                    return (0, 0.0);
                }
                (ev, (miss as f64 / ev as f64) / budget)
            };
            let (_, fast_burn) = burn_over(self.cfg.fast_window_s);
            let (slow_events, slow_burn) = burn_over(self.cfg.slow_window_s);
            w.fast_burn = fast_burn;
            w.slow_burn = slow_burn;

            let raw = if slow_events < self.cfg.min_requests {
                AlarmState::Ok
            } else if fast_burn >= self.cfg.breach_mult
                && slow_burn >= self.cfg.breach_mult
            {
                AlarmState::Breach
            } else if slow_burn > 1.0 {
                AlarmState::Warn
            } else {
                AlarmState::Ok
            };
            let published = w.alarm.observe(raw);

            // lazy gauge registration: a class publishes series only
            // once it has seen traffic
            if w.gauges.is_none() && submitted > 0 {
                let n = class.name();
                w.gauges = Some(ClassGauges {
                    p99: self.metrics.gauge(&format!("class_{n}_p99_s")),
                    goodput: self.metrics.gauge(&format!("class_{n}_goodput_rps")),
                    attainment: self
                        .metrics
                        .gauge(&format!("class_{n}_slo_attainment")),
                    alarm: self.metrics.gauge(&format!("class_{n}_slo_alarm")),
                });
            }
            if let Some(g) = &w.gauges {
                if w.p99_s.is_finite() {
                    g.p99.set(w.p99_s);
                }
                g.goodput.set(w.goodput_rps);
                let terminal = completed + shed;
                if terminal > 0 {
                    g.attainment.set(in_slo as f64 / terminal as f64);
                }
                g.alarm.set(published.level() as f64);
            }
        }
    }

    // ----- readers ------------------------------------------------------

    pub fn status(&self, class: Class) -> SloStatus {
        let i = class.index();
        let h = &self.handles[i];
        let st = self.state();
        let w = &st.classes[i];
        let completed = h.completed.get();
        let shed = h.shed.get();
        let in_slo = h.in_slo.get();
        let terminal = completed + shed;
        SloStatus {
            class,
            target_s: self.cfg.targets_s[i],
            submitted: h.submitted.get(),
            completed,
            shed,
            deferred: h.deferred.get(),
            in_slo,
            attainment: if terminal == 0 {
                f64::NAN
            } else {
                in_slo as f64 / terminal as f64
            },
            p99_s: w.p99_s,
            goodput_rps: w.goodput_rps,
            fast_burn: w.fast_burn,
            slow_burn: w.slow_burn,
            alarm: w.alarm.current(),
        }
    }

    /// All classes, in [`Class::ALL`] order.
    pub fn statuses(&self) -> Vec<SloStatus> {
        Class::ALL.iter().map(|c| self.status(*c)).collect()
    }

    /// Wire shape for `{"cmd":"slo"}` (non-finite numbers serialize as
    /// null per the JSON writer's contract).
    pub fn to_json(&self) -> Json {
        let classes = self
            .statuses()
            .into_iter()
            .map(|s| {
                let mut o = JsonObj::new();
                o.insert("class", Json::str(s.class.name()));
                o.insert("target_s", Json::num(s.target_s));
                o.insert("submitted", Json::num(s.submitted as f64));
                o.insert("completed", Json::num(s.completed as f64));
                o.insert("shed", Json::num(s.shed as f64));
                o.insert("deferred", Json::num(s.deferred as f64));
                o.insert("in_slo", Json::num(s.in_slo as f64));
                o.insert("attainment", Json::num(s.attainment));
                o.insert("p99_s", Json::num(s.p99_s));
                o.insert("goodput_rps", Json::num(s.goodput_rps));
                o.insert("fast_burn", Json::num(s.fast_burn));
                o.insert("slow_burn", Json::num(s.slow_burn));
                o.insert("alarm", Json::str(s.alarm.name()));
                Json::Obj(o)
            })
            .collect();
        let mut o = JsonObj::new();
        o.insert("classes", Json::Arr(classes));
        o.insert("goal", Json::num(self.cfg.goal));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SloConfig {
        SloConfig {
            targets_s: [0.05, 0.25, 2.0],
            goal: 0.9,
            fast_window_s: 2.0,
            slow_window_s: 10.0,
            breach_mult: 2.0,
            hysteresis: 2,
            min_requests: 4,
        }
    }

    #[test]
    fn empty_class_window_is_nan_and_elides_gauges() {
        let metrics = Metrics::new();
        let slo = SloObservatory::new(cfg(), &metrics);
        slo.tick(1.0);
        for s in slo.statuses() {
            assert!(s.p99_s.is_nan(), "{:?}", s.class);
            assert!(s.attainment.is_nan());
            assert_eq!(s.goodput_rps, 0.0);
            assert_eq!(s.alarm, AlarmState::Ok);
        }
        // no traffic -> no gauges registered at all: the prom / stats
        // surfaces stay free of zero-value class series
        let prom = metrics.render_prom();
        assert!(!prom.contains("class_premium_slo_attainment"), "{prom}");
        assert!(!prom.contains("class_batch_p99_s"), "{prom}");
        // counters ARE pre-resolved (hot-path handles) and render as 0
        assert!(prom.contains("class_premium_submitted 0"), "{prom}");
    }

    #[test]
    fn attainment_counts_sheds_as_misses() {
        let metrics = Metrics::new();
        let slo = SloObservatory::new(cfg(), &metrics);
        for _ in 0..8 {
            slo.record_submitted(Class::Premium);
        }
        for _ in 0..6 {
            slo.record_completed(Class::Premium, 0.01); // in SLO
        }
        slo.record_completed(Class::Premium, 1.0); // late
        slo.record_shed(Class::Premium);
        slo.tick(1.0);
        let s = slo.status(Class::Premium);
        assert_eq!((s.submitted, s.completed, s.shed), (8, 7, 1));
        assert_eq!(s.in_slo, 6);
        assert!((s.attainment - 0.75).abs() < 1e-12, "{}", s.attainment);
        // exactly-once: submitted == completed + shed
        assert_eq!(s.submitted, s.completed + s.shed);
        // gauges registered now, and agree with the status
        let prom = metrics.render_prom();
        assert!(prom.contains("class_premium_slo_attainment 0.75"), "{prom}");
    }

    #[test]
    fn burn_alarm_latches_breach_and_recovers_with_hysteresis() {
        let metrics = Metrics::new();
        let slo = SloObservatory::new(cfg(), &metrics);
        // all-miss traffic: burn = (1.0 miss rate) / 0.1 budget = 10x
        let feed_bad = |slo: &SloObservatory| {
            for _ in 0..10 {
                slo.record_shed(Class::Premium);
            }
            slo.tick(1.0);
        };
        feed_bad(&slo);
        // raw Breach but hysteresis=2 holds the published state at Ok
        assert_eq!(slo.status(Class::Premium).alarm, AlarmState::Ok);
        feed_bad(&slo);
        assert_eq!(slo.status(Class::Premium).alarm, AlarmState::Breach);
        assert!(slo.status(Class::Premium).fast_burn >= 2.0);
        // recovery: all-good traffic must outweigh the slow window's
        // remembered misses before the raw verdict drops, then the
        // streak must fill before the published state moves
        let feed_good = |slo: &SloObservatory| {
            for _ in 0..400 {
                slo.record_completed(Class::Premium, 0.01);
            }
            slo.tick(4.0);
        };
        feed_good(&slo);
        assert_eq!(
            slo.status(Class::Premium).alarm,
            AlarmState::Breach,
            "one good window must not clear a latched breach"
        );
        feed_good(&slo);
        feed_good(&slo);
        assert_eq!(slo.status(Class::Premium).alarm, AlarmState::Ok);
    }

    #[test]
    fn thin_evidence_never_pages() {
        let metrics = Metrics::new();
        let slo = SloObservatory::new(cfg(), &metrics);
        // 3 sheds < min_requests 4: raw verdict stays Ok forever
        for _ in 0..3 {
            slo.record_shed(Class::Batch);
        }
        for _ in 0..10 {
            slo.tick(0.5);
        }
        assert_eq!(slo.status(Class::Batch).alarm, AlarmState::Ok);
    }

    #[test]
    fn windowed_p99_recovers_after_an_overload() {
        let metrics = Metrics::new();
        let slo = SloObservatory::new(cfg(), &metrics);
        for _ in 0..100 {
            slo.record_completed(Class::Standard, 5.0); // terrible
        }
        slo.tick(1.0);
        assert!(slo.status(Class::Standard).p99_s > 1.0);
        for _ in 0..100 {
            slo.record_completed(Class::Standard, 0.01);
        }
        slo.tick(1.0);
        let p99 = slo.status(Class::Standard).p99_s;
        assert!(p99 < 0.1, "windowed p99 latched the overload: {p99}");
        // and an empty follow-up window is NaN, gauge keeps last value
        slo.tick(1.0);
        assert!(slo.status(Class::Standard).p99_s.is_nan());
    }

    #[test]
    fn concurrent_multi_class_books_are_exactly_once() {
        let metrics = Metrics::new();
        let slo = SloObservatory::new(cfg(), &metrics);
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let slo = Arc::clone(&slo);
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        let class = Class::ALL[(t + i as usize) % Class::COUNT];
                        slo.record_submitted(class);
                        if i % 5 == 0 {
                            slo.record_shed(class);
                        } else {
                            slo.record_completed(class, 0.01);
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        slo.tick(1.0);
        let mut total_sub = 0;
        let mut total_term = 0;
        for s in slo.statuses() {
            assert_eq!(s.submitted, s.completed + s.shed, "{:?}", s.class);
            total_sub += s.submitted;
            total_term += s.completed + s.shed;
        }
        assert_eq!(total_sub, 8 * 500);
        assert_eq!(total_sub, total_term);
    }

    #[test]
    fn to_json_shape() {
        let metrics = Metrics::new();
        let slo = SloObservatory::new(cfg(), &metrics);
        slo.record_submitted(Class::Premium);
        slo.record_completed(Class::Premium, 0.01);
        slo.tick(1.0);
        // roundtrip through the writer: NaN fields must serialize as
        // null (the wire contract `{"cmd":"slo"}` relies on)
        let j = Json::parse(&slo.to_json().to_string()).unwrap();
        let classes = j.get("classes").as_arr().unwrap();
        assert_eq!(classes.len(), Class::COUNT);
        assert_eq!(classes[0].get("class").as_str(), Some("premium"));
        assert_eq!(classes[0].get("completed").as_u64(), Some(1));
        assert_eq!(classes[0].get("alarm").as_str(), Some("ok"));
        // the untouched batch class serialized its NaN attainment as null
        assert!(classes[2].get("attainment").as_f64().is_none());
        assert!((j.get("goal").as_f64().unwrap() - 0.9).abs() < 1e-12);
    }
}
