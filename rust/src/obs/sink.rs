//! Non-blocking append-only JSONL file sink.
//!
//! `append` pushes the line into an in-memory buffer under a short
//! buffer mutex and returns -- it NEVER touches the file, so recording
//! paths (trace spans, controller events) pay no blocking IO.  A
//! background flusher thread swaps the buffer out and writes it every
//! [`FLUSH_INTERVAL`]; [`JsonlSink::flush`] forces the same swap+write
//! synchronously (tests, shutdown).  The buffer is bounded
//! ([`SINK_BUF_CAP`]): if the flusher ever falls behind, further lines
//! are dropped and counted rather than growing memory or blocking the
//! recorder -- tracing is best-effort by design.
//!
//! The flusher holds only a `Weak` to the sink state, so dropping the
//! last [`JsonlSink`] clone flushes the remainder (via `Drop`) and the
//! thread exits on its next tick.

use std::io::Write;
use std::path::Path;
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

/// Max buffered bytes before `append` starts dropping lines.
pub const SINK_BUF_CAP: usize = 4 << 20;

/// How often the background flusher writes the buffer out.
pub const FLUSH_INTERVAL: Duration = Duration::from_millis(100);

#[derive(Default)]
struct SinkBuf {
    data: String,
    dropped: u64,
}

struct SinkInner {
    buf: Mutex<SinkBuf>,
    file: Mutex<std::fs::File>,
}

impl SinkInner {
    /// Swap the buffer out under its lock, write OUTSIDE it: a recorder
    /// appending concurrently never waits on the disk.
    fn flush(&self) {
        let data = {
            let mut b = self.buf.lock().unwrap();
            std::mem::take(&mut b.data)
        };
        if data.is_empty() {
            return;
        }
        // best effort: sink IO errors must never fail the serving path
        let _ = self.file.lock().unwrap().write_all(data.as_bytes());
    }
}

impl Drop for SinkInner {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Shared handle to one append-only JSONL file.  Clones share the
/// buffer and flusher.
#[derive(Clone)]
pub struct JsonlSink {
    inner: Arc<SinkInner>,
}

impl std::fmt::Debug for JsonlSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let b = self.inner.buf.lock().unwrap();
        write!(f, "JsonlSink(buffered={}, dropped={})", b.data.len(), b.dropped)
    }
}

impl JsonlSink {
    /// Open `path` for append (created if missing) and start the
    /// background flusher.
    pub fn open(path: impl AsRef<Path>) -> std::io::Result<JsonlSink> {
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        let inner = Arc::new(SinkInner {
            buf: Mutex::new(SinkBuf::default()),
            file: Mutex::new(file),
        });
        let weak: Weak<SinkInner> = Arc::downgrade(&inner);
        // the flusher must not keep the sink alive: it upgrades per tick
        // and exits once every handle is gone (Drop flushed the rest)
        let _ = std::thread::Builder::new()
            .name("jsonl-sink".to_string())
            .spawn(move || loop {
                std::thread::sleep(FLUSH_INTERVAL);
                match weak.upgrade() {
                    Some(s) => s.flush(),
                    None => break,
                }
            });
        Ok(JsonlSink { inner })
    }

    /// Buffer one line (newline appended).  No file IO, ever: over
    /// capacity the line is dropped and counted instead.
    pub fn append(&self, line: &str) {
        let mut b = self.inner.buf.lock().unwrap();
        if b.data.len() + line.len() + 1 > SINK_BUF_CAP {
            b.dropped += 1;
            return;
        }
        b.data.push_str(line);
        b.data.push('\n');
    }

    /// Synchronously write everything buffered so far (tests, shutdown,
    /// snapshot commands).  Safe to call concurrently with `append`.
    pub fn flush(&self) {
        self.inner.flush();
    }

    /// Lines dropped because the buffer was full (flusher starved).
    pub fn dropped(&self) -> u64 {
        self.inner.buf.lock().unwrap().dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("abc-sink-{}-{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("out.jsonl")
    }

    #[test]
    fn append_buffers_and_flush_writes() {
        let path = tmp("basic");
        let sink = JsonlSink::open(&path).unwrap();
        sink.append(r#"{"a":1}"#);
        sink.append(r#"{"a":2}"#);
        sink.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().nth(1).unwrap().contains("\"a\":2"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn drop_flushes_the_remainder() {
        let path = tmp("drop");
        {
            let sink = JsonlSink::open(&path).unwrap();
            sink.append(r#"{"last":true}"#);
            // no explicit flush: Drop must write it
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("last"), "drop lost the buffer: {text:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn over_capacity_drops_instead_of_blocking() {
        let path = tmp("cap");
        let sink = JsonlSink::open(&path).unwrap();
        let line = "x".repeat(SINK_BUF_CAP / 2);
        sink.append(&line);
        sink.append(&line); // second fills to just under cap? no: drops
        assert!(sink.dropped() >= 1, "cap not enforced");
        sink.flush();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_appends_all_land() {
        let path = tmp("conc");
        let sink = JsonlSink::open(&path).unwrap();
        std::thread::scope(|s| {
            for t in 0..4 {
                let sink = sink.clone();
                s.spawn(move || {
                    for i in 0..100 {
                        sink.append(&format!(r#"{{"t":{t},"i":{i}}}"#));
                    }
                });
            }
        });
        sink.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 400);
        std::fs::remove_file(&path).ok();
    }
}
