//! Drift observatory: live agreement estimation over shadow-sampled
//! exits, calibration-drift gauges, and theta re-grounding.
//!
//! Every theta in the system comes from ONE-SHOT offline calibration
//! (paper §4, Appendix B): `theta = estimate_theta(cal_points, eps)`
//! picks the smallest threshold whose empirical failure rate -- the
//! fraction of selected (early-exited) rows the next tier would have
//! answered differently -- stays within epsilon.  Under distribution
//! drift the agreement curve moves and that guarantee silently rots:
//! the tier keeps exiting at the stale theta while its true failure
//! rate climbs.  Nothing in the request path can see this, because the
//! whole point of an early exit is that the next tier never runs.
//!
//! The observatory closes that blind spot with *shadow sampling*: the
//! router forwards a deterministic 1-in-N fraction of early-exited
//! rows (the [`Tracer`]-style `id % n` idiom, see [`DriftMonitor::sampled`])
//! to the next tier OFF the critical path -- the client already got
//! the early answer; the shadow verdict only produces a
//! [`CalPoint`]-style observation `(score, agree-with-next-tier)`.
//! Those land here, in a bounded per-tier window, and each arrival
//! re-runs [`estimate_theta`] over the window:
//!
//! * `tier_{i}_agreement_live`      -- windowed agreement fraction;
//! * `tier_{i}_empirical_failure_rate` -- windowed disagreement (the
//!   live estimate of the quantity epsilon bounds);
//! * `tier_{i}_theta_live` vs `tier_{i}_theta_cal` -- what calibration
//!   WOULD pick on today's traffic vs what the tier is serving with;
//! * `tier_{i}_drift_alarm`         -- [`AlarmState`] as 0/1/2;
//! * `tier_{i}_shadow_samples`      -- observation count.
//!
//! The [`DriftAlarm`] is a hysteresis state machine (a state change
//! needs `hysteresis` CONSECUTIVE observations of the same candidate
//! state) so a single unlucky window never flaps the alarm.  The
//! shadow rate is *adaptive*: while any tier's published alarm is Warn
//! or Breach the monitor densifies to `max(1, sample_every / 10)` --
//! an alarmed window wants evidence faster -- and restores the
//! configured 1-in-N once every tier is Ok
//! (`drift_shadow_sample_every` gauges the rate in force).  On
//! breach, the opt-in control-plane hook (`serve --recalibrate`) calls
//! [`DriftMonitor::reground`] to re-ground the tier's serving theta
//! from the live estimate -- recorded in the `EventLog` with
//! `decider="drift"`.
//!
//! Everything here is off the request hot path: [`DriftMonitor::sampled`]
//! is a pure modulus on the request id, and the per-tier window Mutex
//! is touched only by the single shadow worker thread, the control
//! loop and wire queries (`scripts/check_hotpath_locks.sh` counts this
//! file's acquisitions in its baseline).
//!
//! [`Tracer`]: crate::obs::trace::Tracer

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::calib::threshold::{estimate_theta, CalPoint, ThetaEstimate};
use crate::metrics::{Counter, Gauge, Metrics};
use crate::util::json::{Json, JsonObj};

/// Shadow-sampling + alarm knobs for the drift observatory.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Shadow 1-in-N early-exited rows through the next tier (0
    /// disables shadowing entirely, 1 shadows every early exit).
    pub sample_every: u64,
    /// Bounded per-tier observation window (oldest points evicted).
    pub window: usize,
    /// The safe-deferral budget the live failure rate is judged
    /// against (paper's epsilon).
    pub epsilon: f64,
    /// Breach when `failure > breach_mult * epsilon`; between epsilon
    /// and the breach line the alarm is Warn.
    pub breach_mult: f64,
    /// Consecutive same-verdict observations required to change alarm
    /// state (clamped to >= 1).
    pub hysteresis: usize,
    /// Below this many windowed observations the alarm stays Ok and
    /// re-grounding refuses to act: no evidence, no alarm.
    pub min_samples: usize,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            sample_every: 100,
            window: 512,
            epsilon: 0.05,
            breach_mult: 2.0,
            hysteresis: 3,
            min_samples: 50,
        }
    }
}

/// Alarm verdict for one tier's safe-deferral guarantee.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlarmState {
    /// Live failure rate within epsilon (or not enough evidence yet).
    Ok,
    /// Live failure rate above epsilon but under the breach line.
    Warn,
    /// Live failure rate above `breach_mult * epsilon`.
    Breach,
}

impl AlarmState {
    /// Wire / log name.
    pub fn name(self) -> &'static str {
        match self {
            AlarmState::Ok => "ok",
            AlarmState::Warn => "warn",
            AlarmState::Breach => "breach",
        }
    }

    /// Gauge encoding: ok=0, warn=1, breach=2.
    pub fn level(self) -> u64 {
        match self {
            AlarmState::Ok => 0,
            AlarmState::Warn => 1,
            AlarmState::Breach => 2,
        }
    }
}

/// Pure hysteresis state machine: the published alarm only moves after
/// `hysteresis` CONSECUTIVE raw observations of the same candidate
/// state, so one unlucky window cannot flap ok -> breach -> ok.
#[derive(Debug, Clone)]
pub struct DriftAlarm {
    current: AlarmState,
    candidate: AlarmState,
    streak: usize,
    hysteresis: usize,
}

impl DriftAlarm {
    /// A fresh alarm in [`AlarmState::Ok`].
    pub fn new(hysteresis: usize) -> Self {
        DriftAlarm {
            current: AlarmState::Ok,
            candidate: AlarmState::Ok,
            streak: 0,
            hysteresis: hysteresis.max(1),
        }
    }

    /// The published state.
    pub fn current(&self) -> AlarmState {
        self.current
    }

    /// Feed one raw per-window verdict; returns the (possibly moved)
    /// published state.  A raw verdict equal to the current state
    /// resets the candidate streak.
    pub fn observe(&mut self, raw: AlarmState) -> AlarmState {
        if raw == self.current {
            self.candidate = self.current;
            self.streak = 0;
            return self.current;
        }
        if raw == self.candidate {
            self.streak += 1;
        } else {
            self.candidate = raw;
            self.streak = 1;
        }
        if self.streak >= self.hysteresis {
            self.current = self.candidate;
            self.streak = 0;
        }
        self.current
    }
}

/// One tier's live drift picture, as served over the wire and consumed
/// by the control plane's drift decider.
#[derive(Debug, Clone, Copy)]
pub struct DriftStatus {
    /// Monitored (early-exiting) tier index.
    pub tier: usize,
    /// Published (hysteresis-filtered) alarm state.
    pub alarm: AlarmState,
    /// All-time shadow observations recorded for this tier.
    pub samples: u64,
    /// Windowed observations currently held.
    pub window: usize,
    /// Windowed agreement fraction with the next tier.
    pub agreement: f64,
    /// Windowed empirical failure rate (disagreement among exits) --
    /// the live estimate of the quantity epsilon bounds.
    pub failure_rate: f64,
    /// The budget the failure rate is judged against.
    pub epsilon: f64,
    /// What [`estimate_theta`] picks on the current window
    /// (`f32::INFINITY` = defer-all sentinel when the window is empty,
    /// `f32::NEG_INFINITY` when every windowed exit agrees).
    pub theta_live: f32,
    /// The threshold the tier is actually serving with (None when the
    /// tier was spawned without an explicit theta).
    pub theta_cal: Option<f32>,
}

struct TierState {
    window: VecDeque<CalPoint>,
    alarm: DriftAlarm,
    live: ThetaEstimate,
    theta_cal: Option<f32>,
    samples: u64,
}

struct TierDrift {
    tier: usize,
    state: Mutex<TierState>,
    samples: Arc<Counter>,
    agreement_gauge: Arc<Gauge>,
    failure_gauge: Arc<Gauge>,
    theta_live_gauge: Arc<Gauge>,
    theta_cal_gauge: Arc<Gauge>,
    alarm_gauge: Arc<Gauge>,
}

impl TierDrift {
    // the ONLY lock acquisition in this file: every path below funnels
    // through here, keeping the hot-path lint baseline at 1
    fn state(&self) -> MutexGuard<'_, TierState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// The per-fleet drift observatory: one bounded observation window +
/// alarm per early-exiting tier (the final tier never exits early and
/// is not monitored), publishing into the fleet's metrics registry so
/// the gauges ride the existing `stats` / `render_prom` surfaces.
pub struct DriftMonitor {
    cfg: DriftConfig,
    tiers: Vec<TierDrift>,
    regrounds: Arc<Counter>,
    /// The shadow rate currently in force (adaptive): while any tier's
    /// published alarm is Warn or Breach the monitor densifies to
    /// 1-in-(N/10) to gather evidence faster, restoring the configured
    /// 1-in-N once every tier is back to Ok.  Atomic so the router's
    /// hot-path [`DriftMonitor::sampled`] check stays lock-free.
    effective_every: AtomicU64,
    effective_gauge: Arc<Gauge>,
}

impl std::fmt::Debug for DriftMonitor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DriftMonitor")
            .field("cfg", &self.cfg)
            .field("tiers", &self.tiers.len())
            .finish()
    }
}

impl DriftMonitor {
    /// Build a monitor for a fleet whose tier `i` serves with
    /// `theta_cal[i]` (`theta_cal.len()` = number of tiers; the last
    /// entry is ignored -- the final tier has no next tier to agree
    /// with).  Gauges and counters are pre-resolved here, once.
    pub fn new(
        cfg: DriftConfig,
        theta_cal: &[Option<f32>],
        metrics: &Metrics,
    ) -> Arc<DriftMonitor> {
        let monitored = theta_cal.len().saturating_sub(1);
        let tiers = (0..monitored)
            .map(|i| {
                let t = TierDrift {
                    tier: i,
                    state: Mutex::new(TierState {
                        window: VecDeque::with_capacity(cfg.window.min(4096)),
                        alarm: DriftAlarm::new(cfg.hysteresis),
                        live: estimate_theta(&[], cfg.epsilon),
                        theta_cal: theta_cal[i],
                        samples: 0,
                    }),
                    samples: metrics.counter(&format!("tier_{i}_shadow_samples")),
                    agreement_gauge: metrics.gauge(&format!("tier_{i}_agreement_live")),
                    failure_gauge: metrics
                        .gauge(&format!("tier_{i}_empirical_failure_rate")),
                    theta_live_gauge: metrics.gauge(&format!("tier_{i}_theta_live")),
                    theta_cal_gauge: metrics.gauge(&format!("tier_{i}_theta_cal")),
                    alarm_gauge: metrics.gauge(&format!("tier_{i}_drift_alarm")),
                };
                // non-finite gauges render as NaN in prom and null in
                // JSON: "no estimate yet", distinguishable from 0.0
                t.theta_live_gauge.set(f64::NAN);
                t.theta_cal_gauge
                    .set(theta_cal[i].map(f64::from).unwrap_or(f64::NAN));
                t
            })
            .collect();
        let effective_gauge = metrics.gauge("drift_shadow_sample_every");
        effective_gauge.set(cfg.sample_every as f64);
        Arc::new(DriftMonitor {
            cfg,
            tiers,
            regrounds: metrics.counter("drift_regrounds_total"),
            effective_every: AtomicU64::new(cfg.sample_every),
            effective_gauge,
        })
    }

    /// The configured knobs.
    pub fn cfg(&self) -> &DriftConfig {
        &self.cfg
    }

    /// Number of monitored (early-exiting) tiers.
    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Deterministic 1-in-N shadow selection -- same idiom as the
    /// request tracer, so a request's shadow fate is reproducible from
    /// its id alone AND the rate in force: 0 never samples, 1 always,
    /// else `id % n == 0`.  `n` is the *effective* (adaptive) rate, see
    /// [`DriftMonitor::effective_sample_every`].
    pub fn sampled(&self, id: u64) -> bool {
        match self.effective_every.load(Ordering::Relaxed) {
            0 => false,
            1 => true,
            n => id % n == 0,
        }
    }

    /// The shadow rate currently in force: the configured
    /// `sample_every` while every tier's published alarm is Ok,
    /// densified to `max(1, sample_every / 10)` while any tier is Warn
    /// or Breach (an alarmed window wants evidence faster; sampling 0
    /// -- shadowing disabled -- never densifies).
    pub fn effective_sample_every(&self) -> u64 {
        self.effective_every.load(Ordering::Relaxed)
    }

    /// Re-derive the effective shadow rate from the published per-tier
    /// alarm gauges (lock-free reads; called after every alarm-moving
    /// update).
    fn retune_sample_rate(&self) {
        if self.cfg.sample_every <= 1 {
            return; // 0 = disabled, 1 already maximal
        }
        let alarmed = self.tiers.iter().any(|t| t.alarm_gauge.get() > 0.0);
        let target = if alarmed {
            (self.cfg.sample_every / 10).max(1)
        } else {
            self.cfg.sample_every
        };
        self.effective_every.store(target, Ordering::Relaxed);
        self.effective_gauge.set(target as f64);
    }

    /// Seed (or correct) a monitored tier's calibrated-theta reference
    /// after construction.  The serve path needs this: its tier specs
    /// carry `theta: None` (the cascade policy itself is the calibrated
    /// operating point), so the fleet cannot pass the reference values
    /// at spawn -- it grounds the `tier_{i}_theta_cal` gauges here
    /// instead.  No-op for the final tier / out-of-range indices.
    pub fn set_theta_cal(&self, tier: usize, theta: Option<f32>) {
        let Some(td) = self.tiers.get(tier) else { return };
        td.state().theta_cal = theta;
        td.theta_cal_gauge
            .set(theta.map(f64::from).unwrap_or(f64::NAN));
    }

    /// Record one shadow observation for `tier`: `point.score` is the
    /// score the tier exited with, `point.correct` whether the next
    /// tier agreed with the early answer.  Re-runs [`estimate_theta`]
    /// over the bounded window and republishes every gauge.
    ///
    /// Note the windowed failure rate here is CONDITIONAL on exit
    /// (disagreements / windowed exits), which upper-bounds the
    /// unconditional P(exit AND wrong) that epsilon budgets -- an
    /// alarm on the conditional rate is therefore conservative.
    pub fn record(&self, tier: usize, point: CalPoint) {
        let Some(td) = self.tiers.get(tier) else { return };
        let mut st = td.state();
        st.samples += 1;
        st.window.push_back(point);
        while st.window.len() > self.cfg.window.max(1) {
            st.window.pop_front();
        }
        let n = st.window.len();
        let agreed = st.window.iter().filter(|p| p.correct).count();
        let agreement = agreed as f64 / n as f64;
        let failure = (n - agreed) as f64 / n as f64;
        st.live = estimate_theta(st.window.make_contiguous(), self.cfg.epsilon);
        let raw = if n < self.cfg.min_samples {
            AlarmState::Ok
        } else if failure > self.cfg.breach_mult * self.cfg.epsilon {
            AlarmState::Breach
        } else if failure > self.cfg.epsilon {
            AlarmState::Warn
        } else {
            AlarmState::Ok
        };
        let published = st.alarm.observe(raw);
        let theta_live = st.live.theta;
        drop(st);
        td.samples.inc();
        td.agreement_gauge.set(agreement);
        td.failure_gauge.set(failure);
        td.theta_live_gauge.set(if theta_live.is_finite() {
            theta_live as f64
        } else {
            f64::NAN
        });
        td.alarm_gauge.set(published.level() as f64);
        self.retune_sample_rate();
    }

    /// The live picture for one monitored tier (None for the final
    /// tier or out-of-range indices).
    pub fn status(&self, tier: usize) -> Option<DriftStatus> {
        let td = self.tiers.get(tier)?;
        let st = td.state();
        let n = st.window.len();
        let agreed = st.window.iter().filter(|p| p.correct).count();
        Some(DriftStatus {
            tier: td.tier,
            alarm: st.alarm.current(),
            samples: st.samples,
            window: n,
            agreement: if n == 0 { 1.0 } else { agreed as f64 / n as f64 },
            failure_rate: if n == 0 {
                0.0
            } else {
                (n - agreed) as f64 / n as f64
            },
            epsilon: self.cfg.epsilon,
            theta_live: st.live.theta,
            theta_cal: st.theta_cal,
        })
    }

    /// All monitored tiers' statuses, in tier order.
    pub fn statuses(&self) -> Vec<DriftStatus> {
        (0..self.tiers.len()).filter_map(|i| self.status(i)).collect()
    }

    /// Total thetas re-grounded over this monitor's lifetime.
    pub fn regrounds(&self) -> u64 {
        self.regrounds.get()
    }

    /// Re-ground `tier`'s theta from the live estimate.  Refuses
    /// (returns None) unless the published alarm is in breach, the
    /// window holds at least `min_samples` observations and the live
    /// theta is finite -- re-grounding onto the defer-all sentinel
    /// would silence the alarm by disabling the tier.  On success the
    /// window is cleared and the alarm reset to Ok so the fresh theta
    /// is judged only on post-reground evidence.
    pub fn reground(&self, tier: usize) -> Option<f32> {
        let td = self.tiers.get(tier)?;
        let mut st = td.state();
        if st.alarm.current() != AlarmState::Breach
            || st.window.len() < self.cfg.min_samples
            || !st.live.theta.is_finite()
        {
            return None;
        }
        let theta = st.live.theta;
        st.theta_cal = Some(theta);
        st.window.clear();
        st.live = estimate_theta(&[], self.cfg.epsilon);
        st.alarm = DriftAlarm::new(self.cfg.hysteresis);
        drop(st);
        td.theta_cal_gauge.set(theta as f64);
        td.theta_live_gauge.set(f64::NAN);
        td.failure_gauge.set(0.0);
        td.alarm_gauge.set(0.0);
        self.retune_sample_rate();
        self.regrounds.inc();
        Some(theta)
    }

    /// Wire body for `{"cmd":"drift"}`: non-finite thetas render as
    /// JSON null (the writer's non-finite contract), so the defer-all
    /// sentinel never corrupts the line protocol.
    pub fn to_json(&self) -> Json {
        let tiers = self
            .statuses()
            .into_iter()
            .map(|s| {
                let mut o = JsonObj::new();
                o.insert("tier", Json::num(s.tier as f64));
                o.insert("alarm", Json::Str(s.alarm.name().to_string()));
                o.insert("samples", Json::num(s.samples as f64));
                o.insert("window", Json::num(s.window as f64));
                o.insert("agreement_live", Json::num(s.agreement));
                o.insert("failure_rate", Json::num(s.failure_rate));
                o.insert("epsilon", Json::num(s.epsilon));
                o.insert("theta_live", Json::num(f64::from(s.theta_live)));
                o.insert(
                    "theta_cal",
                    s.theta_cal
                        .map(|t| Json::num(f64::from(t)))
                        .unwrap_or(Json::Null),
                );
                Json::Obj(o)
            })
            .collect();
        let mut o = JsonObj::new();
        o.insert("tiers", Json::Arr(tiers));
        o.insert("sample_every", Json::num(self.cfg.sample_every as f64));
        o.insert(
            "effective_sample_every",
            Json::num(self.effective_sample_every() as f64),
        );
        o.insert("regrounds", Json::num(self.regrounds.get() as f64));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(cfg: DriftConfig) -> Arc<DriftMonitor> {
        // two tiers -> tier 0 monitored, final tier not
        DriftMonitor::new(cfg, &[Some(0.5), None], &Metrics::new())
    }

    fn pt(score: f32, correct: bool) -> CalPoint {
        CalPoint { score, correct }
    }

    #[test]
    fn empty_window_degrades_to_defer_all_sentinel() {
        let m = monitor(DriftConfig::default());
        let s = m.status(0).expect("tier 0 monitored");
        // matches estimate_theta's empty-set contract exactly
        assert_eq!(s.theta_live, f32::INFINITY);
        assert_eq!(s.failure_rate, 0.0);
        assert_eq!(s.window, 0);
        assert_eq!(s.theta_cal, Some(0.5));
        assert_eq!(s.alarm, AlarmState::Ok);
        // the final tier is never monitored
        assert!(m.status(1).is_none());
        assert_eq!(m.n_tiers(), 1);
    }

    #[test]
    fn all_agree_window_degrades_to_select_all() {
        let m = monitor(DriftConfig { min_samples: 1, ..DriftConfig::default() });
        for i in 0..20 {
            m.record(0, pt(0.5 + (i as f32) * 0.01, true));
        }
        let s = m.status(0).unwrap();
        assert_eq!(s.theta_live, f32::NEG_INFINITY);
        assert_eq!(s.agreement, 1.0);
        assert_eq!(s.alarm, AlarmState::Ok);
    }

    #[test]
    fn window_evicts_oldest_points() {
        let cfg = DriftConfig {
            window: 4,
            min_samples: 1,
            hysteresis: 1,
            ..DriftConfig::default()
        };
        let m = monitor(cfg);
        for _ in 0..6 {
            m.record(0, pt(0.2, false));
        }
        assert_eq!(m.status(0).unwrap().agreement, 0.0);
        // four agreeing points push every disagreement out
        for _ in 0..4 {
            m.record(0, pt(0.9, true));
        }
        let s = m.status(0).unwrap();
        assert_eq!(s.window, 4);
        assert_eq!(s.agreement, 1.0);
        assert_eq!(s.failure_rate, 0.0);
        assert_eq!(s.samples, 10);
    }

    #[test]
    fn alarm_hysteresis_filters_flaps() {
        let mut a = DriftAlarm::new(3);
        assert_eq!(a.observe(AlarmState::Breach), AlarmState::Ok);
        assert_eq!(a.observe(AlarmState::Breach), AlarmState::Ok);
        // a flap back to ok resets the streak
        assert_eq!(a.observe(AlarmState::Ok), AlarmState::Ok);
        assert_eq!(a.observe(AlarmState::Breach), AlarmState::Ok);
        assert_eq!(a.observe(AlarmState::Breach), AlarmState::Ok);
        // third consecutive breach verdict moves the published state
        assert_eq!(a.observe(AlarmState::Breach), AlarmState::Breach);
        // and coming back down needs the same persistence
        assert_eq!(a.observe(AlarmState::Ok), AlarmState::Breach);
        assert_eq!(a.observe(AlarmState::Warn), AlarmState::Breach);
        assert_eq!(a.observe(AlarmState::Ok), AlarmState::Breach);
        assert_eq!(a.observe(AlarmState::Ok), AlarmState::Breach);
        assert_eq!(a.observe(AlarmState::Ok), AlarmState::Ok);
    }

    #[test]
    fn shadow_selection_is_deterministic_id_mod_n() {
        let cfg = DriftConfig { sample_every: 10, ..DriftConfig::default() };
        let a = monitor(cfg);
        let b = monitor(cfg);
        for id in 0..1000u64 {
            assert_eq!(a.sampled(id), id % 10 == 0);
            assert_eq!(a.sampled(id), b.sampled(id));
        }
        assert!(!monitor(DriftConfig { sample_every: 0, ..cfg }).sampled(0));
        assert!(monitor(DriftConfig { sample_every: 1, ..cfg }).sampled(7));
    }

    #[test]
    fn shadow_rate_densifies_on_warn_and_restores_on_ok() {
        let cfg = DriftConfig {
            sample_every: 100,
            window: 64,
            epsilon: 0.05,
            breach_mult: 10.0,
            hysteresis: 1,
            min_samples: 10,
        };
        let m = monitor(cfg);
        assert_eq!(m.effective_sample_every(), 100);
        // failure ~0.1: above epsilon, under the (10x) breach line -> Warn
        for i in 0..100u64 {
            m.record(0, pt(0.9, i % 10 != 0));
        }
        assert_eq!(m.status(0).unwrap().alarm, AlarmState::Warn);
        assert_eq!(m.effective_sample_every(), 10);
        // densified selection is in force: id 10 now samples
        assert!(m.sampled(10));
        assert!(!m.sampled(11));
        // a clean window brings the alarm AND the rate back down
        for _ in 0..64 {
            m.record(0, pt(0.9, true));
        }
        assert_eq!(m.status(0).unwrap().alarm, AlarmState::Ok);
        assert_eq!(m.effective_sample_every(), 100);
        assert!(!m.sampled(10));
        // disabled shadowing never densifies
        let d = monitor(DriftConfig { sample_every: 0, ..cfg });
        for i in 0..100u64 {
            d.record(0, pt(0.9, i % 10 != 0));
        }
        assert_eq!(d.effective_sample_every(), 0);
        assert!(!d.sampled(0));
    }

    #[test]
    fn breach_then_reground_restores_ok_and_clears_window() {
        let cfg = DriftConfig {
            window: 64,
            epsilon: 0.05,
            breach_mult: 2.0,
            hysteresis: 2,
            min_samples: 10,
            ..DriftConfig::default()
        };
        let m = monitor(cfg);
        // no breach below min_samples, and reground refuses
        for _ in 0..9 {
            m.record(0, pt(0.1, false));
        }
        assert_eq!(m.status(0).unwrap().alarm, AlarmState::Ok);
        assert!(m.reground(0).is_none());
        // 70% agree at 0.9, 30% disagree at low scores -> failure 0.3
        // breaches; live theta separates the two score bands
        for i in 0..70 {
            m.record(0, pt(0.9, true));
            if i % 7 < 3 {
                m.record(0, pt(0.1 + (i as f32) * 0.001, false));
            }
        }
        let s = m.status(0).unwrap();
        assert_eq!(s.alarm, AlarmState::Breach);
        assert!(s.failure_rate > 2.0 * cfg.epsilon);
        let theta = m.reground(0).expect("breach + evidence -> reground");
        assert!(theta.is_finite());
        assert!(theta < 0.9, "re-grounded theta must still admit faithful exits");
        let s = m.status(0).unwrap();
        assert_eq!(s.alarm, AlarmState::Ok);
        assert_eq!(s.window, 0);
        assert_eq!(s.theta_cal, Some(theta));
        assert_eq!(s.theta_live, f32::INFINITY);
        // alarm reset: a second reground without fresh evidence refuses
        assert!(m.reground(0).is_none());
        assert_eq!(m.regrounds(), 1);
    }

    #[test]
    fn gauges_publish_into_the_registry() {
        let metrics = Metrics::new();
        let cfg = DriftConfig { min_samples: 1, hysteresis: 1, ..DriftConfig::default() };
        let m = DriftMonitor::new(cfg, &[Some(0.5), None], &metrics);
        for _ in 0..20 {
            m.record(0, pt(0.9, true));
        }
        for _ in 0..20 {
            m.record(0, pt(0.2, false));
        }
        assert_eq!(metrics.counter("tier_0_shadow_samples").get(), 40);
        assert_eq!(metrics.gauge("tier_0_agreement_live").get(), 0.5);
        assert_eq!(metrics.gauge("tier_0_empirical_failure_rate").get(), 0.5);
        assert_eq!(metrics.gauge("tier_0_drift_alarm").get(), 2.0);
        assert_eq!(metrics.gauge("tier_0_theta_cal").get(), 0.5);
        // theta_live separates the bands: every 0.2-disagreement is
        // refused, every 0.9-agreement still exits
        let live = metrics.gauge("tier_0_theta_live").get();
        assert!(live >= 0.2 && live < 0.9, "live theta {live}");
        // drift JSON carries the same picture
        let j = m.to_json();
        let tiers = j.get("tiers").as_arr().expect("tiers array").to_vec();
        assert_eq!(tiers.len(), 1);
        assert_eq!(tiers[0].get("alarm").as_str(), Some("breach"));
    }
}
