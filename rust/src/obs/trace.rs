//! Per-request trace spans: what a request did, where, for how long.
//!
//! A request's *trace* is the set of [`SpanRecord`]s sharing its id.
//! Spans are emitted independently by whichever component measured them
//! (pool admission, pipeline flush, fleet router) and assembled at READ
//! time ([`Tracer::snapshot_traces`]) -- the collector pattern: the hot
//! path never correlates, it only appends.
//!
//! Sampling is deterministic by request id (`id % N == 0`), so every
//! hop of a sampled request is sampled without any shared decision
//! state, `--trace-sample 1` captures everything, and a sequential id
//! stream yields exactly 1-in-N traces (property-tested in
//! rust/tests/obs_integration.rs).
//!
//! The ring is a fixed array of per-slot micro-locks indexed by an
//! atomic head: writers never contend with each other except on a wrap
//! race, and a snapshot locks each slot only long enough to clone it.
//! Recording a span is one `fetch_add` + one uncontended `Mutex` slot
//! store (+ a buffered [`JsonlSink::append`] when `--trace-file` is
//! set) -- no registry locks, no file IO.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::obs::sink::JsonlSink;
use crate::util::json::{Json, JsonObj};

/// Max retained spans; older entries are overwritten (and counted via
/// [`Tracer::dropped`]).
pub const TRACE_RING_CAPACITY: usize = 8192;

/// What a span measured.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Request admitted (zero-duration marker at arrival).
    Enqueue,
    /// Waiting in a replica's batcher queue (enqueue -> batch flush).
    QueueWait,
    /// How long the flushed batch spent assembling (oldest member's
    /// wait); one per batch, attributed to its first sampled member.
    BatchAssembly,
    /// Classifier execution of the request's batch at one tier.
    Infer,
    /// Deferral hop: the full stay at a tier that answered "defer".
    Defer,
    /// Shed by admission control (terminal).
    Shed,
    /// Answered (terminal); `tier` is the exit tier, duration is the
    /// end-to-end latency.
    Complete,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::BatchAssembly => "batch_assembly",
            SpanKind::Infer => "infer",
            SpanKind::Defer => "defer",
            SpanKind::Shed => "shed",
            SpanKind::Complete => "complete",
        }
    }
}

/// One timed observation of one request at one place in the stack.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    pub request_id: u64,
    pub kind: SpanKind,
    /// Tier index the span happened at (0 for monolithic pools; the
    /// exit tier for `Complete`).
    pub tier: usize,
    /// Wall-clock seconds since the UNIX epoch at span end.
    pub ts_s: f64,
    /// Measured duration (0 for point markers like `Enqueue`).
    pub dur_s: f64,
    /// SLO class of the request, recorded on terminal spans so a trace
    /// carries its tenant.  `None` (the pre-class default and the
    /// non-terminal hops) is omitted from JSON -- existing consumers
    /// parse unchanged.
    pub class: Option<&'static str>,
}

impl SpanRecord {
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("request_id", Json::num(self.request_id as f64));
        o.insert("kind", Json::str(self.kind.name()));
        o.insert("tier", Json::num(self.tier as f64));
        o.insert("ts_s", Json::num(self.ts_s));
        o.insert("dur_s", Json::num(self.dur_s));
        if let Some(class) = self.class {
            o.insert("class", Json::str(class));
        }
        Json::Obj(o)
    }
}

/// Sampled span collector: deterministic 1-in-N admission, bounded
/// ring, optional JSONL mirror.  One per serving deployment, shared by
/// the pool/fleet and every pipeline under it (see
/// [`crate::obs::ObsHook`]).
pub struct Tracer {
    sample_every: u64,
    /// `(seq, span)` slots; seq orders a snapshot and detects wraps.
    slots: Vec<Mutex<Option<(u64, SpanRecord)>>>,
    head: AtomicU64,
    /// Wall clock anchored once: span timestamps are epoch + a cheap
    /// monotonic elapsed, not a `SystemTime::now` syscall per span.
    epoch_unix_s: f64,
    epoch: Instant,
    sink: Option<JsonlSink>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Tracer(sample_every={}, recorded={})",
            self.sample_every,
            self.recorded()
        )
    }
}

impl Tracer {
    /// A tracer sampling every `sample_every`-th request id (0 disables
    /// recording entirely, 1 captures every request).
    pub fn new(sample_every: u64) -> Arc<Tracer> {
        Tracer::build(sample_every, None)
    }

    /// Like [`Tracer::new`], mirroring every span into a JSONL sink
    /// (`serve --trace-file`).
    pub fn with_sink(sample_every: u64, sink: JsonlSink) -> Arc<Tracer> {
        Tracer::build(sample_every, Some(sink))
    }

    fn build(sample_every: u64, sink: Option<JsonlSink>) -> Arc<Tracer> {
        Arc::new(Tracer {
            sample_every,
            slots: (0..TRACE_RING_CAPACITY).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
            epoch_unix_s: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0),
            epoch: Instant::now(),
            sink,
        })
    }

    pub fn sample_every(&self) -> u64 {
        self.sample_every
    }

    /// Deterministic sampling decision: the SAME id answers the same at
    /// every hop, with no shared state.
    pub fn sampled(&self, request_id: u64) -> bool {
        match self.sample_every {
            0 => false,
            1 => true,
            n => request_id % n == 0,
        }
    }

    /// Wall-clock now, from the anchored epoch (cheap).
    pub fn now_s(&self) -> f64 {
        self.epoch_unix_s + self.epoch.elapsed().as_secs_f64()
    }

    /// Record one span.  Callers gate on [`Tracer::sampled`] first; the
    /// cost is one atomic bump + one (uncontended) slot lock.
    pub fn record(&self, request_id: u64, kind: SpanKind, tier: usize, dur_s: f64) {
        self.record_with_class(request_id, kind, tier, dur_s, None);
    }

    /// [`Tracer::record`] carrying the request's SLO class (terminal
    /// spans: shed / complete).
    pub fn record_with_class(
        &self,
        request_id: u64,
        kind: SpanKind,
        tier: usize,
        dur_s: f64,
        class: Option<&'static str>,
    ) {
        let span = SpanRecord {
            request_id,
            kind,
            tier,
            ts_s: self.now_s(),
            dur_s,
            class,
        };
        if let Some(sink) = &self.sink {
            sink.append(&span.to_json().to_string());
        }
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let i = (seq % TRACE_RING_CAPACITY as u64) as usize;
        *self.slots[i].lock().unwrap() = Some((seq, span));
    }

    /// Total spans ever recorded.
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Spans evicted from the ring (history is a suffix).
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(TRACE_RING_CAPACITY as u64)
    }

    /// Force the JSONL mirror (if any) to disk.
    pub fn flush(&self) {
        if let Some(sink) = &self.sink {
            sink.flush();
        }
    }

    /// Retained spans, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut entries: Vec<(u64, SpanRecord)> = self
            .slots
            .iter()
            .filter_map(|s| s.lock().unwrap().clone())
            .collect();
        entries.sort_by_key(|(seq, _)| *seq);
        entries.into_iter().map(|(_, span)| span).collect()
    }

    /// Retained spans grouped per request (ascending request id), the
    /// wire `{"cmd":"traces"}` body:
    /// `[{"request_id": .., "spans": [{kind,tier,ts_s,dur_s}, ..]}, ..]`.
    pub fn snapshot_traces(&self) -> Json {
        let mut by_req: std::collections::BTreeMap<u64, Vec<SpanRecord>> =
            std::collections::BTreeMap::new();
        for span in self.snapshot() {
            by_req.entry(span.request_id).or_default().push(span);
        }
        Json::Arr(
            by_req
                .into_iter()
                .map(|(id, spans)| {
                    let mut o = JsonObj::new();
                    o.insert("request_id", Json::num(id as f64));
                    o.insert(
                        "spans",
                        Json::Arr(
                            spans
                                .iter()
                                .map(|s| {
                                    let mut so = JsonObj::new();
                                    so.insert("kind", Json::str(s.kind.name()));
                                    so.insert("tier", Json::num(s.tier as f64));
                                    so.insert("ts_s", Json::num(s.ts_s));
                                    so.insert("dur_s", Json::num(s.dur_s));
                                    if let Some(class) = s.class {
                                        so.insert("class", Json::str(class));
                                    }
                                    Json::Obj(so)
                                })
                                .collect(),
                        ),
                    );
                    Json::Obj(o)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic_by_id() {
        let t0 = Tracer::new(0);
        let t1 = Tracer::new(1);
        let t10 = Tracer::new(10);
        for id in 0..100u64 {
            assert!(!t0.sampled(id), "disabled tracer sampled {id}");
            assert!(t1.sampled(id), "sample=1 skipped {id}");
            assert_eq!(t10.sampled(id), id % 10 == 0, "id {id}");
        }
    }

    #[test]
    fn record_and_snapshot_order() {
        let t = Tracer::new(1);
        t.record(7, SpanKind::Enqueue, 0, 0.0);
        t.record(7, SpanKind::QueueWait, 0, 0.001);
        t.record(7, SpanKind::Complete, 2, 0.004);
        let spans = t.snapshot();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].kind, SpanKind::Enqueue);
        assert_eq!(spans[2].kind, SpanKind::Complete);
        assert_eq!(spans[2].tier, 2);
        assert!(spans[0].ts_s > 0.0);
        assert!(spans[2].ts_s >= spans[0].ts_s);
        assert_eq!(t.recorded(), 3);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let t = Tracer::new(1);
        let n = TRACE_RING_CAPACITY as u64 + 16;
        for i in 0..n {
            t.record(i, SpanKind::Infer, 0, 0.0);
        }
        assert_eq!(t.recorded(), n);
        assert_eq!(t.dropped(), 16);
        let spans = t.snapshot();
        assert_eq!(spans.len(), TRACE_RING_CAPACITY);
        // suffix survives: the oldest retained span is request 16
        assert_eq!(spans[0].request_id, 16);
        assert_eq!(spans.last().unwrap().request_id, n - 1);
    }

    #[test]
    fn traces_group_by_request() {
        let t = Tracer::new(1);
        t.record(2, SpanKind::Enqueue, 0, 0.0);
        t.record(1, SpanKind::Enqueue, 0, 0.0);
        t.record(2, SpanKind::Complete, 1, 0.002);
        let traces = t.snapshot_traces();
        let arr = traces.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("request_id").as_u64(), Some(1));
        assert_eq!(arr[1].get("request_id").as_u64(), Some(2));
        let spans2 = arr[1].get("spans").as_arr().unwrap();
        assert_eq!(spans2.len(), 2);
        assert_eq!(spans2[1].get("kind").as_str(), Some("complete"));
        assert_eq!(spans2[1].get("tier").as_u64(), Some(1));
    }

    #[test]
    fn concurrent_recording_loses_nothing_under_capacity() {
        let t = Tracer::new(1);
        std::thread::scope(|s| {
            for w in 0..8u64 {
                let t = &t;
                s.spawn(move || {
                    for i in 0..500u64 {
                        t.record(w * 1000 + i, SpanKind::Infer, 0, 0.0);
                    }
                });
            }
        });
        assert_eq!(t.recorded(), 4000);
        assert_eq!(t.snapshot().len(), 4000);
    }

    #[test]
    fn sink_mirrors_spans_as_jsonl() {
        let dir = std::env::temp_dir()
            .join(format!("abc-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let t = Tracer::with_sink(1, JsonlSink::open(&path).unwrap());
        t.record(3, SpanKind::Shed, 1, 0.0);
        t.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        let v = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("request_id").as_u64(), Some(3));
        assert_eq!(v.get("kind").as_str(), Some("shed"));
        assert_eq!(v.get("tier").as_u64(), Some(1));
    }

    #[test]
    fn class_rides_terminal_spans_and_is_omitted_elsewhere() {
        let t = Tracer::new(1);
        t.record(5, SpanKind::Enqueue, 0, 0.0);
        t.record_with_class(5, SpanKind::Complete, 1, 0.004, Some("premium"));
        let spans = t.snapshot();
        assert_eq!(spans[0].class, None);
        assert_eq!(spans[1].class, Some("premium"));
        // JSON: class only where tagged
        assert!(!spans[0].to_json().to_string().contains("\"class\""));
        assert_eq!(spans[1].to_json().get("class").as_str(), Some("premium"));
        let traces = t.snapshot_traces();
        let inner = traces.as_arr().unwrap()[0].get("spans");
        let inner = inner.as_arr().unwrap();
        assert!(!inner[0].to_string().contains("\"class\""));
        assert_eq!(inner[1].get("class").as_str(), Some("premium"));
    }
}
