//! Observability: per-request trace spans and the non-blocking JSONL
//! sink they (and the controller event log) flush through.
//!
//! The serving hot path must never pay a registry-map lock or a
//! blocking file write per request (ROADMAP's hot-path audit; DESIGN.md
//! §12).  This module is the instrumentation that respects that
//! contract:
//!
//! * [`Tracer`] -- 1-in-N sampled per-request [`SpanRecord`]s (enqueue,
//!   queue-wait, batch-assembly, per-tier infer, defer hop, shed,
//!   complete) into a bounded ring of per-slot micro-locks.  Recording
//!   a span costs one atomic index bump plus one uncontended slot lock;
//!   unsampled requests pay a single branch.
//! * [`JsonlSink`] -- append-only JSONL file sink whose `append` only
//!   pushes into an in-memory buffer; a background flusher thread owns
//!   all file IO.  Shared by `--trace-file` and the event log's
//!   `--events-file`.
//! * [`ObsHook`] -- how a `ReplicaPool`/`Pipeline` learns which tracer
//!   (if any) it reports into, which tier it is, and whether it owns
//!   the request's terminal spans.
//!
//! * [`drift`] -- the drift observatory: shadow-sampled live agreement
//!   estimation per tier ([`DriftMonitor`]), calibration-drift gauges
//!   (`tier_{i}_theta_live` vs `tier_{i}_theta_cal`,
//!   `tier_{i}_empirical_failure_rate` vs epsilon) and the hysteresis
//!   [`DriftAlarm`] the control plane's `--recalibrate` hook acts on.
//!   The hot-path contribution is one `id % n` branch; windows and
//!   estimation live on the shadow worker thread.
//!
//! * [`slo`] -- the SLO observatory: per-class (tenant) exactly-once
//!   books, windowed latency/goodput/attainment gauges
//!   (`class_{c}_p99_s`, `class_{c}_goodput_rps`,
//!   `class_{c}_slo_attainment`) and a two-window error-budget
//!   burn-rate alarm per class riding the same hysteresis machine as
//!   the drift alarm.  Hot-path contribution: pre-resolved striped
//!   counters only; all windowed math is refresh-time.
//!
//! Wire surface: `{"cmd":"traces"}` (spans grouped per request),
//! `{"cmd":"drift"}` (per-tier drift statuses), `{"cmd":"slo"}`
//! (per-class SLO statuses) and `repro stats --traces` / `--drift` /
//! `--slo`; the derived per-tier queue-wait/service-time histograms and
//! the drift/SLO gauges land in the metrics registry and are scrapeable
//! via `{"cmd":"prom"}` ([`crate::metrics::Metrics::render_prom`]).

pub mod drift;
pub mod sink;
pub mod slo;
pub mod trace;

use std::sync::Arc;

pub use drift::{AlarmState, DriftAlarm, DriftConfig, DriftMonitor, DriftStatus};
pub use sink::JsonlSink;
pub use slo::{SloConfig, SloObservatory, SloStatus};
pub use trace::{SpanKind, SpanRecord, Tracer, TRACE_RING_CAPACITY};

/// How a serving component reports into the tracing layer.  Cloned into
/// every pipeline a pool spawns, so it must stay cheap to clone.
#[derive(Clone, Debug)]
pub struct ObsHook {
    /// The shared tracer; `None` disables span recording entirely (the
    /// per-request cost is then zero branches past the `Option` check).
    pub tracer: Option<Arc<Tracer>>,
    /// Tier index spans from this component carry (0 for monolithic
    /// pools; the fleet's 0-based tier otherwise -- matches the
    /// `tier_{i}_*` metric naming).
    pub tier: usize,
    /// Whether this component owns the request's terminal spans
    /// (enqueue / shed / complete).  True for monolithic pools; false
    /// for a fleet's tier pools, where the router emits them.
    pub terminal: bool,
}

impl Default for ObsHook {
    fn default() -> Self {
        ObsHook { tracer: None, tier: 0, terminal: true }
    }
}

impl ObsHook {
    /// Hook for a monolithic pool: tier 0, owns terminal spans.
    pub fn monolithic(tracer: Option<Arc<Tracer>>) -> ObsHook {
        ObsHook { tracer, tier: 0, terminal: true }
    }

    /// Hook for one tier of a fleet: the router owns terminal spans.
    pub fn for_tier(tracer: Option<Arc<Tracer>>, tier: usize) -> ObsHook {
        ObsHook { tracer, tier, terminal: false }
    }

    /// The tracer, when one is attached AND sampling is enabled.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref().filter(|t| t.sample_every() > 0)
    }
}
