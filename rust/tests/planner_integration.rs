//! Integration: the gear planner's online controller against on-off
//! load -- no PJRT artifacts needed (synthetic backend).
//!
//! Covers the claims the subsystem exists for:
//! * under an on-off trace at 2x the top gear's saturation, the
//!   adaptive controller completes strictly more work (sheds strictly
//!   less) than the fixed top gear;
//! * after the load ends the controller shifts back up to the top gear
//!   within one dwell period (plus sampling slack);
//! * gear shifts never drop or duplicate an in-flight request, under
//!   both open-loop load and adversarial shift churn.
//!
//! Timing margins follow loadgen_integration.rs: the synthetic
//! classifier's sleep-based service time is a *lower* bound on real
//! elapsed time, so a slow CI machine only lowers capacity -- and every
//! comparison below is against a baseline that the same slowdown hurts
//! at least as much.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use abc_serve::control::{ControlConfig, ControlLoop, ControlTarget, ControllerConfig};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::planner::{Gear, GearHandle, GearPlan};
use abc_serve::trafficgen::{LoadGen, SyntheticClassifier, Trace};

const DIM: usize = 4;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 16;
/// 2ms per row, batches of 8: the top gear sustains ~500 rows/s on one
/// replica regardless of host speed (sleep only overshoots).
const PER_ROW: Duration = Duration::from_millis(2);
/// The fast gear runs at a quarter of the top gear's per-row compute.
const FAST_WORK: f64 = 0.25;
const DWELL: Duration = Duration::from_millis(200);

/// Wall-clock tests run one at a time (same pattern as
/// loadgen_integration.rs).
static TIMING_LOCK: Mutex<()> = Mutex::new(());

fn classifier() -> Arc<SyntheticClassifier> {
    Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW))
}

fn top_capacity_rps() -> f64 {
    classifier().capacity_rps(MAX_BATCH)
}

/// Two-gear ladder over the synthetic backend: the top gear is the
/// plain classifier (work 1.0), the fast gear trades accuracy for 4x
/// throughput.  `sustainable_rps` matches the classifier's actual
/// capacities so the controller's watermarks mean what they say.
fn plan() -> GearPlan {
    let cap = top_capacity_rps();
    let gear = |acc: f64, work: f64, rps: f64| Gear {
        id: 0,
        k: 3,
        epsilon: 0.03,
        theta: 0.6,
        mid: vec![],
        max_batch: MAX_BATCH,
        replicas: 1,
        tier_fleet: vec![],
        dollar_per_req: 0.0,
        accuracy: acc,
        relative_cost: work,
        sustainable_rps: rps,
    };
    GearPlan::new(vec![
        gear(0.95, 1.0, cap),
        gear(0.85, FAST_WORK, cap / FAST_WORK),
    ])
    .unwrap()
}

fn pool_cfg() -> PoolConfig {
    PoolConfig {
        replicas: 1,
        max_queue: MAX_QUEUE,
        batcher: BatcherConfig {
            max_batch: MAX_BATCH,
            max_wait: Duration::from_millis(1),
        },
        ..PoolConfig::default()
    }
}

fn controller_cfg() -> ControllerConfig {
    ControllerConfig {
        sample_every: Duration::from_millis(10),
        dwell: DWELL,
        ..ControllerConfig::default()
    }
}

/// On-off trace at 2x the top gear's saturation during on-windows.
fn onoff_trace(n: usize) -> Arc<Trace> {
    let rate = 2.0 * top_capacity_rps();
    Arc::new(Trace::synth(
        Arrival::OnOff { rate, on_s: 0.3, off_s: 0.3 },
        n,
        DIM,
        17,
    ))
}

#[test]
fn adaptive_beats_fixed_top_gear_under_onoff_overload() {
    let _serial = TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let n = 600;
    let trace = onoff_trace(n);
    let gen = LoadGen { workers: 64, class_mix: None };

    // ---- fixed top gear: the plain pool IS the top gear (work 1.0) ----
    let fixed_pool = Arc::new(ReplicaPool::spawn(classifier(), pool_cfg(), Metrics::new()));
    let fixed = gen
        .run(&fixed_pool, Arc::clone(&trace), &Metrics::new())
        .unwrap();

    // ---- adaptive: same pool shape + controller over the gear plan ----
    let plan = plan();
    let handle = GearHandle::new(plan.top().config());
    let metrics = Metrics::new();
    let adaptive_pool = Arc::new(ReplicaPool::spawn_geared(
        classifier(),
        pool_cfg(),
        Arc::clone(&metrics),
        Arc::clone(&handle),
    ));
    // the unified control plane in gear-only mode: one loop thread,
    // walking the plan ladder through the pool's shared gear handle
    let mut controller = ControlLoop::spawn(
        Arc::clone(&adaptive_pool) as Arc<dyn ControlTarget>,
        ControlConfig::gear_plan(plan, controller_cfg()),
    );
    let adaptive = gen
        .run(&adaptive_pool, Arc::clone(&trace), &Metrics::new())
        .unwrap();

    // per-request accounting: nothing dropped, nothing duplicated, no
    // failures -- on BOTH sides of the comparison
    assert_eq!(fixed.errors, 0, "{fixed:?}");
    assert_eq!(adaptive.errors, 0, "{adaptive:?}");
    assert_eq!(fixed.completed + fixed.shed, n as u64, "{fixed:?}");
    assert_eq!(adaptive.completed + adaptive.shed, n as u64, "{adaptive:?}");
    assert_eq!(fixed_pool.total_outstanding(), 0);
    assert_eq!(adaptive_pool.total_outstanding(), 0);

    // the fixed top gear at 2x saturation must shed; the controller must
    // have reacted by downshifting at least once
    assert!(fixed.shed > 0, "fixed gear at 2x saturation never shed: {fixed:?}");
    assert!(
        metrics.counter("gear_shift_down").get() > 0,
        "controller never downshifted; metrics: {:?}",
        metrics.snapshot()
    );

    // headline: strictly higher goodput, strictly fewer sheds
    assert!(
        adaptive.completed > fixed.completed,
        "adaptive {} vs fixed {} completed",
        adaptive.completed,
        fixed.completed
    );
    assert!(
        adaptive.shed < fixed.shed,
        "adaptive {} vs fixed {} shed",
        adaptive.shed,
        fixed.shed
    );

    // after the load ends the controller must restore the top gear
    // within one dwell period (plus sampling/EWMA-decay slack)
    let deadline = std::time::Instant::now() + DWELL + Duration::from_millis(300);
    loop {
        if handle.gear_id() == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "controller stuck in gear {} after the burst; metrics: {:?}",
            handle.gear_id(),
            metrics.snapshot()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        metrics.counter("gear_shift_up").get() > 0,
        "no upshift recorded"
    );
    controller.stop();
}

#[test]
fn shift_churn_never_drops_or_duplicates_requests() {
    let _serial = TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let plan = plan();
    let handle = GearHandle::new(plan.top().config());
    // fast classifier so the test is about the swap path, not capacity
    let fast = Arc::new(SyntheticClassifier::new(
        DIM,
        3,
        Duration::ZERO,
        Duration::from_micros(50),
    ));
    let pool = Arc::new(ReplicaPool::spawn_geared(
        fast,
        PoolConfig {
            replicas: 2,
            max_queue: 256,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
        Arc::clone(&handle),
    ));

    // adversarial churn: swap gears + retune batchers as fast as possible
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let churn = {
        let handle = Arc::clone(&handle);
        let pool = Arc::clone(&pool);
        let plan = plan.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut i = 0usize;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                let gear = &plan.gears[i % plan.len()];
                handle.store(gear.config());
                pool.set_max_batch(1 + i % 8);
                i += 1;
                std::thread::sleep(Duration::from_micros(200));
            }
            i
        })
    };

    // hammer the pool from several submitter threads
    let n_threads = 4u64;
    let per_thread = 250u64;
    let submitters: Vec<_> = (0..n_threads)
        .map(|t| {
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || {
                let mut answered = Vec::new();
                for i in 0..per_thread {
                    let id = t * per_thread + i;
                    let req = abc_serve::types::Request {
                        id,
                        features: vec![0.5; DIM],
                        arrival_s: 0.0,
                        class: abc_serve::types::Class::Standard,
                    };
                    let v = pool.infer(req).expect("infer under churn");
                    answered.push(v.request_id);
                }
                answered
            })
        })
        .collect();
    let mut all: Vec<u64> = Vec::new();
    for s in submitters {
        all.extend(s.join().unwrap());
    }
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let swaps = churn.join().unwrap();

    // exactly-once accounting: every id answered, none twice
    all.sort_unstable();
    let expect: Vec<u64> = (0..n_threads * per_thread).collect();
    assert_eq!(all, expect, "dropped or duplicated requests under churn");
    assert_eq!(pool.total_outstanding(), 0);
    assert!(swaps > 10, "churn thread barely ran ({swaps} swaps)");
    assert_eq!(handle.generation(), swaps as u64);
}
