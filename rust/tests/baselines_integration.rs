//! Integration: baselines over real artifacts (WoC) and the API-LLM
//! simulator (FrugalGPT / AutoMix / MoT), checking the paper's headline
//! comparative shapes.

use std::sync::Arc;

use abc_serve::baselines::api_policies::{
    run_abc_voting, run_automix, run_frugal_gpt, run_mot, run_single_model,
    AutoMixKind,
};
use abc_serve::baselines::woc;
use abc_serve::calib;
use abc_serve::coordinator::cascade::Cascade;
use abc_serve::runtime::engine::Engine;
use abc_serve::sim::api_llm::{best_of_tier, build_agents, default_tasks, generate_samples};
use abc_serve::types::RuleKind;
use abc_serve::util::rng::Rng;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(root).unwrap())
}

#[test]
fn woc_runs_and_abc_is_pareto_competitive() {
    let Some(m) = manifest() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let rt = Arc::new(SuiteRuntime::load(engine, &m, "synth-cifar10", true).unwrap());
    let val = rt.dataset(&m, "val").unwrap();
    let test = rt.dataset(&m, "test").unwrap();
    let test = test.slice(0, 4000);
    let flops: Vec<f64> = rt
        .suite
        .tiers
        .iter()
        .map(|t| t.flops_per_sample_member as f64)
        .collect();
    let woc_rep = woc::tune_and_run(&rt.singles, &val, &test, &flops).unwrap();
    assert!(woc_rep.accuracy > 0.5, "WoC sane accuracy");
    let total: f64 = woc_rep.exit_fractions.iter().sum();
    assert!((total - 1.0).abs() < 1e-9);

    // ABC with the same ladder
    let cal = calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 100, 0.05).unwrap();
    let cascade = Cascade::new(rt.tiers.clone(), cal.policy.clone());
    let (_, abc) = cascade.evaluate(&test.x, &test.y, test.n).unwrap();
    let mut reach = 1.0;
    let mut abc_flops = 0.0;
    for (t, &e) in rt.suite.tiers.iter().zip(&abc.exit_fractions) {
        abc_flops += reach * t.flops_per_sample_member as f64;
        reach -= e;
    }
    // Fig. 2 shape: ABC at least matches WoC accuracy, or is cheaper at
    // comparable accuracy.
    assert!(
        abc.accuracy >= woc_rep.accuracy - 0.01
            || abc_flops < woc_rep.mean_flops,
        "ABC (acc {:.4}, flops {:.2e}) dominated by WoC (acc {:.4}, flops {:.2e})",
        abc.accuracy,
        abc_flops,
        woc_rep.accuracy,
        woc_rep.mean_flops
    );
}

#[test]
fn fig5_shape_abc_pareto_dominates_baselines() {
    // The paper's Fig. 5 claim: ABC "matches their accuracy at
    // significantly lower costs in all tasks".  Concretely: for EVERY
    // baseline, some ABC operating point (majority or unanimity voting)
    // costs no more and is within 1.5 accuracy points (usually above).
    for task in default_tasks() {
        let samples = generate_samples(&task);
        let agents = build_agents(&task);
        let tiers = [1usize, 2, 3];
        let abc_maj =
            run_abc_voting(&task, &samples, &agents, &tiers, 0.34, &mut Rng::new(11));
        let abc_unan =
            run_abc_voting(&task, &samples, &agents, &tiers, 0.67, &mut Rng::new(16));
        let baselines = vec![
            run_frugal_gpt(&task, &samples, &agents, &tiers, 0.6, &mut Rng::new(12)),
            run_automix(&task, &samples, &agents, &tiers, AutoMixKind::Threshold, &mut Rng::new(13)),
            run_automix(&task, &samples, &agents, &tiers, AutoMixKind::Pomdp, &mut Rng::new(14)),
            run_mot(&task, &samples, &agents, &tiers, 5, 0.8, &mut Rng::new(15)),
        ];
        for b in &baselines {
            let covered = [&abc_maj, &abc_unan].iter().any(|abc| {
                abc.usd_per_query <= b.usd_per_query * 1.02
                    && abc.accuracy >= b.accuracy - 0.015
            });
            assert!(
                covered,
                "{}: {} (acc {:.3}, ${:.5}) not covered by ABC points \
                 maj(acc {:.3}, ${:.5}) / unan(acc {:.3}, ${:.5})",
                task.name,
                b.policy,
                b.accuracy,
                b.usd_per_query,
                abc_maj.accuracy,
                abc_maj.usd_per_query,
                abc_unan.accuracy,
                abc_unan.usd_per_query
            );
        }
    }
}

#[test]
fn fig5_shape_cost_reduction_vs_gpt4_class_model() {
    // Paper: 2-25x reduction in average price vs always using the top
    // model.  Check the 405B-only policy costs several times ABC.
    let task = &default_tasks()[0]; // gsm8k: long generations
    let samples = generate_samples(task);
    let agents = build_agents(task);
    let abc = run_abc_voting(task, &samples, &agents, &[1, 2, 3], 0.34, &mut Rng::new(21));
    let big = run_single_model(task, &samples, best_of_tier(&agents, 3), &mut Rng::new(22));
    let reduction = big.usd_per_query / abc.usd_per_query;
    assert!(
        reduction > 2.0,
        "expected >2x cost reduction vs 405B-only, got {reduction:.2}x"
    );
    assert!(abc.accuracy >= big.accuracy - 0.02);
}

#[test]
fn automix_always_pricier_than_abc() {
    // Paper App. D.2: "it can be guaranteed that ABC will always be
    // cheaper to use than AutoMix".
    for task in default_tasks() {
        let samples = generate_samples(&task);
        let agents = build_agents(&task);
        for kind in [AutoMixKind::Threshold, AutoMixKind::Pomdp] {
            let abc =
                run_abc_voting(&task, &samples, &agents, &[1, 2, 3], 0.34, &mut Rng::new(31));
            let am = run_automix(&task, &samples, &agents, &[1, 2, 3], kind, &mut Rng::new(32));
            assert!(
                abc.usd_per_query < am.usd_per_query,
                "{}: ABC {:.5} vs {} {:.5}",
                task.name,
                abc.usd_per_query,
                am.policy,
                am.usd_per_query
            );
        }
    }
}
