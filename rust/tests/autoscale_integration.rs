//! Integration: the elastic replica autoscaler -- no PJRT artifacts
//! needed (synthetic backend).
//!
//! Covers the claims the subsystem exists for:
//! * **drain correctness**: under continuous multi-threaded load with
//!   adversarial scale up/down churn, `completed + shed == submitted`
//!   EXACTLY -- no drops, no duplicates -- and a draining replica never
//!   admits new work once `drain()` returns;
//! * **rental win**: under on-off load the elastic pool tracks the
//!   fixed-max-fleet pool's goodput while consuming measurably fewer
//!   replica-seconds, scaling up into bursts and draining back to the
//!   floor afterwards;
//! * the autoscaler's telemetry (gauges, scale counters, event log)
//!   reflects what happened.
//!
//! Timing margins follow loadgen_integration.rs: the synthetic
//! classifier's sleep-based service time is a *lower* bound on real
//! elapsed time, so a slow CI machine only lowers capacity -- and every
//! comparison below is against a baseline the same slowdown hurts at
//! least as much.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use abc_serve::control::{
    ControlConfig, ControlLoop, ControlTarget, ControllerConfig, ScaleConfig,
};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, PoolError, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::planner::{Gear, GearHandle, GearPlan};
use abc_serve::trafficgen::{LoadGen, SyntheticClassifier, Trace};
use abc_serve::types::{Class, Request};

const DIM: usize = 4;
const MAX_BATCH: usize = 8;
/// 2ms per row, batches of 8: one replica sustains ~500 rows/s
/// regardless of host speed (sleep only overshoots).
const PER_ROW: Duration = Duration::from_millis(2);
const MAX_REPLICAS: usize = 4;

/// Wall-clock tests run one at a time (same pattern as
/// loadgen_integration.rs).
static TIMING_LOCK: Mutex<()> = Mutex::new(());

fn classifier() -> Arc<SyntheticClassifier> {
    Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW))
}

fn per_replica_rps() -> f64 {
    classifier().capacity_rps(MAX_BATCH)
}

/// One-gear plan: isolates replica elasticity from gear shifting (the
/// coupled decision itself is unit-tested in control::decider).
fn one_gear_plan() -> GearPlan {
    GearPlan::new(vec![Gear {
        id: 0,
        k: 3,
        epsilon: 0.03,
        theta: 0.6,
        mid: vec![],
        max_batch: MAX_BATCH,
        replicas: 1,
        tier_fleet: vec![],
        dollar_per_req: 0.0,
        accuracy: 0.95,
        relative_cost: 1.0,
        sustainable_rps: per_replica_rps(),
    }])
    .unwrap()
}

fn pool_cfg(replicas: usize) -> PoolConfig {
    PoolConfig {
        replicas,
        max_queue: 64,
        batcher: BatcherConfig {
            max_batch: MAX_BATCH,
            max_wait: Duration::from_millis(1),
        },
        ..PoolConfig::default()
    }
}

#[test]
fn drain_churn_accounts_every_request_exactly_once() {
    let _serial = TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    // fast classifier so the test exercises the scale path, not capacity
    let fast = Arc::new(SyntheticClassifier::new(
        DIM,
        3,
        Duration::ZERO,
        Duration::from_micros(50),
    ));
    let pool = Arc::new(ReplicaPool::spawn(
        fast,
        PoolConfig {
            replicas: 2,
            max_queue: 256,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
    ));

    // adversarial churn: drain + re-provision + advance as fast as possible
    let stop = Arc::new(AtomicBool::new(false));
    let churn = {
        let pool = Arc::clone(&pool);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut cycles = 0usize;
            while !stop.load(Ordering::SeqCst) {
                pool.drain(1);
                pool.scale_up(1, Duration::ZERO);
                pool.advance(Instant::now());
                cycles += 1;
                std::thread::sleep(Duration::from_micros(300));
            }
            // settle: retire whatever is still draining
            for _ in 0..200 {
                pool.advance(Instant::now());
                if pool.counts().2 == 0 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            cycles
        })
    };

    // hammer the pool from several submitter threads; count every outcome
    let n_threads = 4u64;
    let per_thread = 250u64;
    let submitters: Vec<_> = (0..n_threads)
        .map(|t| {
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || {
                let mut answered = Vec::new();
                let mut shed = 0u64;
                for i in 0..per_thread {
                    let id = t * per_thread + i;
                    let req = Request {
                        id,
                        features: vec![0.5; DIM],
                        arrival_s: 0.0,
                        class: Class::Standard,
                    };
                    match pool.infer(req) {
                        Ok(v) => answered.push(v.request_id),
                        Err(PoolError::Overloaded { .. }) => shed += 1,
                        Err(e) => panic!("request {id} failed under churn: {e}"),
                    }
                }
                (answered, shed)
            })
        })
        .collect();
    let mut all: Vec<u64> = Vec::new();
    let mut shed_total = 0u64;
    for s in submitters {
        let (answered, shed) = s.join().unwrap();
        all.extend(answered);
        shed_total += shed;
    }
    stop.store(true, Ordering::SeqCst);
    let cycles = churn.join().unwrap();

    // exactly-once accounting: completed + shed == submitted, no id
    // answered twice, nothing silently lost
    let submitted = n_threads * per_thread;
    assert_eq!(all.len() as u64 + shed_total, submitted);
    all.sort_unstable();
    all.dedup();
    assert_eq!(
        all.len() as u64 + shed_total,
        submitted,
        "duplicate verdicts under churn"
    );
    assert_eq!(pool.total_outstanding(), 0);
    assert!(cycles > 10, "churn thread barely ran ({cycles} cycles)");
    // the lifecycle genuinely cycled: replicas were retired and replaced
    assert!(
        pool.metrics().counter("replicas_retired").get() > 0,
        "churn never retired a replica"
    );
    assert!(pool.replica_seconds() > 0.0);
    // the pool still serves after all that
    pool.infer(Request {
        id: 9999,
        features: vec![0.5; DIM],
        arrival_s: 0.0,
        class: Class::Standard,
    })
    .unwrap();
}

#[test]
fn elastic_pool_matches_fixed_goodput_with_fewer_replica_seconds() {
    let _serial = TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    // bursts at 60% of the max fleet's capacity: the fixed-max pool
    // absorbs them outright, the elastic pool must scale into them
    let burst_rps = 0.6 * MAX_REPLICAS as f64 * per_replica_rps();
    let n = 700;
    let trace = Arc::new(Trace::synth(
        Arrival::OnOff { rate: burst_rps, on_s: 0.4, off_s: 0.5 },
        n,
        DIM,
        31,
    ));
    let gen = LoadGen { workers: 64, class_mix: None };

    // ---- fixed-N baseline: max fleet pinned for the whole run ----
    let fixed_pool = Arc::new(ReplicaPool::spawn(
        classifier(),
        pool_cfg(MAX_REPLICAS),
        Metrics::new(),
    ));
    let fixed = gen.run(&fixed_pool, Arc::clone(&trace), &Metrics::new()).unwrap();
    let fixed_rs = fixed_pool.replica_seconds();

    // ---- elastic: autoscaler over the same classifier, 1..=4 fleet ----
    let plan = one_gear_plan();
    let handle = GearHandle::new(plan.top().config());
    let metrics = Metrics::new();
    let elastic_pool = Arc::new(ReplicaPool::spawn_geared(
        classifier(),
        pool_cfg(1),
        Arc::clone(&metrics),
        Arc::clone(&handle),
    ));
    // the unified control plane: ONE loop thread making the gear and
    // scale decision from the same observation each tick
    let mut autoscaler = ControlLoop::spawn(
        Arc::clone(&elastic_pool) as Arc<dyn ControlTarget>,
        ControlConfig::autoscaled(
            plan,
            ControllerConfig {
                sample_every: Duration::from_millis(10),
                dwell: Duration::from_millis(80),
                ..ControllerConfig::default()
            },
            ScaleConfig {
                min_replicas: 1,
                max_replicas: MAX_REPLICAS,
                warmup: Duration::ZERO,
                ..ScaleConfig::default()
            },
            0.0,
        ),
    );
    let elastic = gen
        .run(&elastic_pool, Arc::clone(&trace), &Metrics::new())
        .unwrap();
    let elastic_rs = elastic_pool.replica_seconds();

    // exact per-request accounting on both sides
    assert_eq!(fixed.errors, 0, "{fixed:?}");
    assert_eq!(elastic.errors, 0, "{elastic:?}");
    assert_eq!(fixed.completed + fixed.shed, n as u64, "{fixed:?}");
    assert_eq!(elastic.completed + elastic.shed, n as u64, "{elastic:?}");

    // the autoscaler actually scaled, both directions
    assert!(
        metrics.counter("scale_up_total").get() > 0,
        "never scaled up; metrics: {:?}",
        metrics.snapshot()
    );
    assert!(
        metrics.counter("scale_down_total").get() > 0,
        "never scaled down; metrics: {:?}",
        metrics.snapshot()
    );
    // ...and logged its decisions
    let events = metrics.events().snapshot();
    assert!(
        events.iter().any(|e| e.kind == abc_serve::metrics::EventKind::Scale),
        "no scale events logged"
    );

    // headline: goodput within 10% of the always-max fleet (the 5%
    // target is asserted as the bench's verdict under calmer
    // conditions; CI boxes get slack here) at measurably lower rent
    assert!(
        elastic.completed as f64 >= 0.90 * fixed.completed as f64,
        "elastic {} vs fixed {} completed",
        elastic.completed,
        fixed.completed
    );
    assert!(
        elastic_rs < 0.85 * fixed_rs,
        "no rental win: elastic {elastic_rs:.2} vs fixed {fixed_rs:.2} replica-s"
    );

    // after the load ends the fleet drains back to the floor
    let deadline = Instant::now() + Duration::from_secs(3);
    loop {
        let (warming, live, draining) = elastic_pool.counts();
        if warming == 0 && draining == 0 && live == 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "fleet stuck at {:?}; metrics: {:?}",
            elastic_pool.counts(),
            metrics.snapshot()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // lifecycle gauges ended consistent with the drained fleet (give
    // the sampler a few ticks to publish the final state)
    std::thread::sleep(Duration::from_millis(60));
    assert_eq!(metrics.gauge("replicas_live").get(), 1.0);
    assert!(metrics.gauge("replica_seconds").get() > 0.0);
    autoscaler.stop();
}
