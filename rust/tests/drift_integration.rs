//! Integration: the drift observatory end to end -- shadow sampling,
//! breach detection, and closed-loop theta re-grounding -- on the
//! StagedSynthetic drifting workload (no PJRT artifacts needed).
//!
//! Covers the claims the subsystem exists for:
//! * **stale policy rots silently, the observatory sees it**: under a
//!   drifting workload a fixed-policy fleet keeps answering drifted
//!   rows wrong; the shadow path scores the early exits against the
//!   next tier, the live failure rate crosses `breach_mult * epsilon`,
//!   and the alarm latches Breach while the request books stay
//!   exactly-once with shadowing active;
//! * **`--recalibrate` closes the loop**: the control plane's
//!   [`DriftDecider`] re-grounds the breached tier's theta from the
//!   live windowed estimate (recorded with `decider="drift"`), after
//!   which the drifted population defers to undrifted tiers, every
//!   client answer is canonical again, and the tier's empirical
//!   failure rate sits back under epsilon -- while the fixed-theta
//!   fleet of the first test never leaves Breach.
//!
//! Determinism: the synthetic drift fixture reports ONE constant score
//! (`0.9 * frac`) for every drifted exit, so `estimate_theta` sees the
//! wrong population as a single tie-group, refuses it atomically, and
//! lands on exactly that constant -- no dependence on window phase or
//! shadow-drop timing.  The routed tier, drift lane and canonical
//! prediction are all pure integer arithmetic on the request id,
//! replicated by the helpers below.
//!
//! [`DriftDecider`]: abc_serve::control::DriftDecider

use std::sync::Arc;
use std::time::{Duration, Instant};

use abc_serve::control::{
    ControlConfig, ControlLoop, ControlTarget, ControllerConfig, TierControl,
};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::StageClassifier;
use abc_serve::coordinator::router::{TierSpec, TieredFleet, TieredFleetConfig};
use abc_serve::cost::rental::Gpu;
use abc_serve::metrics::{EventKind, Metrics};
use abc_serve::obs::{AlarmState, DriftConfig};
use abc_serve::trafficgen::{StagedSynthetic, SyntheticClassifier};
use abc_serve::types::Request;

const DIM: usize = 4;
const LEVELS: usize = 3;
const MAX_QUEUE: usize = 256;
/// Fast stages: drift detection needs observation volume, not
/// saturation -- the whole cascade costs 150us per row.
const PER_ROW: Duration = Duration::from_micros(150);
const WEIGHTS: [f64; 3] = [0.15, 0.25, 0.60];
/// 30% of the row population drifts...
const DRIFT_FRAC: f64 = 0.3;
/// ...and every drifted exit reports this constant score (the
/// StagedSynthetic drift contract: `0.9 * frac`).
const DRIFT_SCORE: f32 = 0.9 * 0.3;
/// Concurrent submitters per wave (bounded by the tier queues).
const WAVE: usize = 150;

fn drifting_stage() -> Arc<StagedSynthetic> {
    let inner = SyntheticClassifier::new(DIM, LEVELS, Duration::ZERO, PER_ROW);
    Arc::new(
        StagedSynthetic::new(inner, WEIGHTS.to_vec()).with_drift(DRIFT_FRAC),
    )
}

fn drift_cfg() -> DriftConfig {
    DriftConfig {
        sample_every: 1, // shadow every early exit: max signal
        window: 256,
        epsilon: 0.05,
        breach_mult: 2.0,
        hysteresis: 2,
        min_samples: 40,
    }
}

fn spawn_fleet() -> (Arc<TieredFleet>, Arc<Metrics>) {
    let metrics = Metrics::new();
    let fleet = Arc::new(
        TieredFleet::spawn_with_drift(
            drifting_stage() as Arc<dyn StageClassifier>,
            TieredFleetConfig {
                tiers: vec![
                    TierSpec::fixed(Gpu::V100, 2, MAX_QUEUE),
                    TierSpec::fixed(Gpu::A6000, 2, MAX_QUEUE),
                    TierSpec::fixed(Gpu::H100, 1, MAX_QUEUE),
                ],
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_micros(200),
                },
                class_weights: None,
            },
            Arc::clone(&metrics),
            None,
            Some(drift_cfg()),
        )
        .unwrap(),
    );
    (fleet, metrics)
}

fn req(id: u64) -> Request {
    Request {
        id,
        features: vec![id as f32 * 0.61 - 7.0, 0.0, 0.0, 0.0],
        arrival_s: 0.0,
        class: abc_serve::types::Class::Standard,
    }
}

/// The SyntheticClassifier's routing hash for `req(id)` -- the same
/// f32 arithmetic the backend runs, so every expectation below is
/// exact, not statistical.
fn hash(id: u64) -> usize {
    ((id as f32 * 0.61 - 7.0).abs() * 997.0) as usize
}

/// Canonical (undrifted) prediction for `req(id)`.
fn canonical(id: u64) -> u32 {
    (hash(id) % 2) as u32
}

/// 1-based routed exit tier for `req(id)`.
fn routed(id: u64) -> usize {
    1 + hash(id) % LEVELS
}

/// Whether drift mode claims `req(id)` -- the exact comparison
/// StagedSynthetic's lane hash runs (f64 on the right: `0.3 * 1000.0`
/// is just under 300).
fn drifted(id: u64) -> bool {
    let lane = (hash(id) / LEVELS).wrapping_mul(2_654_435_761) % 1000;
    (lane as f64) < DRIFT_FRAC * 1000.0
}

/// Drive `ids` through the fleet in bounded concurrent waves; every
/// request must complete (the load is far under capacity).  Returns
/// `(id, prediction, exit_tier)` per request.
fn run_ids(fleet: &TieredFleet, ids: std::ops::Range<u64>) -> Vec<(u64, u32, usize)> {
    let all: Vec<u64> = ids.collect();
    let mut out = Vec::with_capacity(all.len());
    for chunk in all.chunks(WAVE) {
        let mut got: Vec<(u64, u32, usize)> = std::thread::scope(|s| {
            let handles: Vec<_> = chunk
                .iter()
                .map(|&id| {
                    s.spawn(move || {
                        let v = fleet.infer(req(id)).expect("shed under light load");
                        (id, v.prediction, v.exit_tier)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        out.append(&mut got);
    }
    out
}

/// Wait until the shadow worker has drained everything serving
/// submitted (every successfully enqueued shadow job is either scored
/// into the monitor or counted shed) and no request is outstanding.
fn wait_shadow_drained(fleet: &TieredFleet, metrics: &Metrics) {
    let m = fleet.drift().expect("observatory attached");
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let submitted = metrics.counter("shadow_submitted").get();
        let shed = metrics.counter("shadow_shed").get();
        let scored: u64 =
            (0..m.n_tiers()).map(|t| m.status(t).unwrap().samples).sum();
        if scored + shed == submitted && fleet.total_outstanding() == 0 {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "shadow never drained: scored {scored} + shed {shed} != \
             submitted {submitted}, outstanding {}",
            fleet.total_outstanding()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// A stale fixed policy under drift: clients get wrong answers, the
/// observatory's live failure rate crosses the breach line and latches,
/// the live theta re-derives the drifted score band exactly, and the
/// request books stay exactly-once with the shadow path active.  With
/// no recalibration loop attached this fleet STAYS in breach -- the
/// report-only contrast for the closed-loop test below.
#[test]
fn stale_theta_breaches_while_books_stay_exact() {
    let (fleet, metrics) = spawn_fleet();
    let n = 600u64;
    let got = run_ids(&fleet, 0..n);

    // deterministic serving picture: every row exits at its routed
    // tier; drifted rows that exit early answer WRONG (flipped), the
    // final tier answers canonically even for drifted rows
    let mut wrong = 0u64;
    for (id, prediction, exit_tier) in got {
        assert_eq!(exit_tier, routed(id), "id {id} exited off its route");
        let flips = drifted(id) && routed(id) < LEVELS;
        let want = if flips { canonical(id) ^ 1 } else { canonical(id) };
        assert_eq!(prediction, want, "id {id}");
        wrong += u64::from(flips);
    }
    assert!(
        wrong > n / 10,
        "drift fixture must hurt a stale policy: {wrong} wrong of {n}"
    );

    wait_shadow_drained(&fleet, &metrics);
    // exactly-once with shadowing active: the shadow path re-runs rows
    // through downstream pools but never touches the fleet books
    assert_eq!(metrics.counter("fleet_submitted").get(), n);
    assert_eq!(metrics.counter("fleet_completed").get(), n);
    assert_eq!(metrics.counter("fleet_shed").get(), 0);

    let m = fleet.drift().expect("observatory attached");
    assert_eq!(m.n_tiers(), LEVELS - 1, "final tier is never monitored");
    let s = m.status(0).expect("tier 0 monitored");
    // ~1/3 of rows route to tier 1; ~30% of those drifted -> far over
    // the 2 * epsilon = 0.1 breach line
    assert!(s.samples >= 100, "too few shadow observations: {s:?}");
    assert!(
        s.failure_rate > 2.0 * s.epsilon,
        "stale tier 0 must breach: {s:?}"
    );
    assert_eq!(s.alarm, AlarmState::Breach, "{s:?}");
    // the wrong population is one tie-group at the constant drifted
    // score: estimate_theta refuses it atomically and lands exactly on
    // the score that fences it (strict > acceptance)
    assert!(
        (s.theta_live - DRIFT_SCORE).abs() < 1e-5,
        "live theta {} != drifted constant {DRIFT_SCORE}",
        s.theta_live
    );
    // gauges ride the fleet registry (the stats / prom surface)
    assert_eq!(metrics.gauge("tier_0_drift_alarm").get(), 2.0);
    assert!(metrics.gauge("tier_0_empirical_failure_rate").get() > 0.1);
    // no recalibration loop: serving theta stays stale, alarm latched
    assert_eq!(fleet.tier_theta(0), None);
    assert_eq!(m.regrounds(), 0);
}

/// The closed loop: a control plane with `recalibrate` armed observes
/// the breach, re-grounds the tier's serving theta from the live
/// estimate (EventLog `decider="drift"`), and fresh traffic then serves
/// every answer canonically with the tier's empirical failure rate back
/// under epsilon -- the acceptance bar for `serve --recalibrate`.
#[test]
fn recalibrate_regrounds_theta_and_restores_epsilon() {
    let (fleet, metrics) = spawn_fleet();
    let stage = drifting_stage();
    let tiers: Vec<TierControl> = (0..LEVELS)
        .map(|i| TierControl {
            per_replica_rps: stage.stage_capacity_rps(i, 4),
            scale: None,   // fixed fleets: the drift decider acts alone
            rungs: vec![], // no gear ladders either
        })
        .collect();
    let mut cfg = ControlConfig::tiered(
        tiers,
        ControllerConfig {
            sample_every: Duration::from_millis(10),
            dwell: Duration::from_millis(80),
            ..ControllerConfig::default()
        },
        0.0,
    );
    cfg.recalibrate = true;
    let mut control =
        ControlLoop::spawn(Arc::clone(&fleet) as Arc<dyn ControlTarget>, cfg);

    // ---- phase 1: drift under the stale policy ----
    let n1 = 600u64;
    let got = run_ids(&fleet, 0..n1);
    // the breach cannot latch before min_samples stale exits were
    // served, so some phase-1 clients necessarily got wrong answers
    let wrong1 = got
        .iter()
        .filter(|(id, p, _)| *p != canonical(*id))
        .count();
    assert!(wrong1 >= 1, "phase 1 never served a drifted answer");

    // both early tiers breach (each sees its own drifted exits) and the
    // control loop re-grounds their serving thetas from the live
    // estimate -- exactly the drifted constant, per the tie-group
    // argument in the module docs
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if fleet.tier_theta(0).is_some() && fleet.tier_theta(1).is_some() {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "recalibration never fired: thetas {:?}/{:?}, drift {}, events {}",
            fleet.tier_theta(0),
            fleet.tier_theta(1),
            fleet.drift().unwrap().to_json().to_string(),
            metrics.events().to_jsonl()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    for t in 0..2 {
        let theta = fleet.tier_theta(t).unwrap();
        assert!(
            (theta - DRIFT_SCORE).abs() < 1e-5,
            "tier {t} re-grounded to {theta}, want {DRIFT_SCORE}"
        );
    }
    let m = fleet.drift().unwrap();
    assert!(m.regrounds() >= 2, "both early tiers must re-ground");
    assert!(metrics.counter("drift_reground_total").get() >= 2);
    let events = metrics.events().snapshot();
    assert!(
        events.iter().any(|e| {
            e.kind == EventKind::Shift
                && e.decider == "drift"
                && e.trigger == "breach"
                && e.tier == 0
        }),
        "no drift re-ground event for tier 0: {}",
        metrics.events().to_jsonl()
    );

    // ---- phase 2: fresh traffic on the re-grounded thetas ----
    // drifted rows now score at (not above) the strict threshold at
    // every early tier, defer to an undrifted tier, and come back
    // canonical: recalibration restored answers, not just telemetry
    let n2 = 600u64;
    let got = run_ids(&fleet, 1000..1000 + n2);
    for (id, prediction, _) in got {
        assert_eq!(prediction, canonical(id), "id {id} wrong after re-ground");
    }

    wait_shadow_drained(&fleet, &metrics);
    // the re-grounded tier's live failure rate is back under epsilon
    // (the reground cleared its window: post-reground evidence only)
    let s = m.status(0).expect("tier 0 monitored");
    assert!(s.window >= 100, "too little post-reground evidence: {s:?}");
    assert!(
        s.failure_rate <= s.epsilon,
        "re-ground failed to restore epsilon: {s:?}"
    );
    assert_eq!(s.alarm, AlarmState::Ok, "{s:?}");

    // exactly-once across both phases, shadow active, loop attached
    assert_eq!(metrics.counter("fleet_submitted").get(), n1 + n2);
    assert_eq!(metrics.counter("fleet_completed").get(), n1 + n2);
    assert_eq!(metrics.counter("fleet_shed").get(), 0);
    assert_eq!(fleet.total_outstanding(), 0);
    control.stop();
}
