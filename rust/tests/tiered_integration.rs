//! Integration: the tiered fleet (pool-per-tier, routed deferral,
//! heterogeneous rental pricing) -- no PJRT artifacts needed
//! (StagedSynthetic backend).
//!
//! Covers the claims the subsystem exists for:
//! * **answer preservation**: routing stages between pools produces
//!   byte-identical results (preds, exit levels, scores, exit
//!   fractions) to the monolithic `classify_batch` on the same inputs;
//! * **rental win (§5.2.2)**: under on-off load at 2x the monolithic
//!   pool's saturation, a tiered fleet with cheap GPUs on the early
//!   tiers and ONE expensive top pool matches (here: beats) the
//!   monolithic pool's goodput while spending measurably fewer
//!   fleet-dollars (`cost::rental` accounting), with exactly-once
//!   request accounting across tier handoffs, shedding at depth, and a
//!   mid-run drain of an interior tier's pool;
//! * the per-tier autoscaler grows tiers independently into a burst,
//!   drains them back to their floors, and logs its decisions.
//!
//! Timing margins follow autoscale_integration.rs: the synthetic
//! stage's sleep-based service time is a *lower* bound on real elapsed
//! time, so a slow CI machine only lowers capacity -- and every
//! comparison below is against a baseline the same slowdown hurts at
//! least as much.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use abc_serve::control::{
    ControlConfig, ControlLoop, ControlTarget, ControllerConfig, ScaleConfig,
    TierControl, TierRung,
};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::{BatchClassifier, StageClassifier};
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::coordinator::router::{TierSpec, TieredFleet, TieredFleetConfig};
use abc_serve::cost::rental::Gpu;
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::trafficgen::{LoadGen, StagedSynthetic, SyntheticClassifier, Trace};
use abc_serve::types::Request;

const DIM: usize = 4;
const LEVELS: usize = 3;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 32;
/// 2ms per row through the WHOLE cascade: one monolithic replica
/// sustains ~500 rows/s regardless of host speed (sleep only
/// overshoots).
const PER_ROW: Duration = Duration::from_millis(2);
/// Per-tier share of the monolithic per-row cost: cheap tier 1, pricey
/// top model (the fleet shape §5.2.2 prices).
const WEIGHTS: [f64; 3] = [0.15, 0.25, 0.60];
const MONO_REPLICAS: usize = 4;

/// Wall-clock tests run one at a time (same pattern as
/// loadgen_integration.rs / autoscale_integration.rs).
static TIMING_LOCK: Mutex<()> = Mutex::new(());

fn inner() -> SyntheticClassifier {
    SyntheticClassifier::new(DIM, LEVELS, Duration::ZERO, PER_ROW)
}

fn staged() -> Arc<StagedSynthetic> {
    Arc::new(StagedSynthetic::new(inner(), WEIGHTS.to_vec()))
}

fn mono_capacity_rps() -> f64 {
    MONO_REPLICAS as f64 * inner().capacity_rps(MAX_BATCH)
}

fn batcher() -> BatcherConfig {
    BatcherConfig { max_batch: MAX_BATCH, max_wait: Duration::from_millis(1) }
}

/// The §5.2.2 placement under test: two cheap tiers, one expensive top
/// pool.  Tier 2 is drainable mid-run (floor 1, starts at 2).
fn fleet_spec() -> Vec<TierSpec> {
    vec![
        TierSpec::fixed(Gpu::V100, 2, MAX_QUEUE),
        TierSpec {
            gpu: Gpu::A6000,
            replicas: 2,
            min_replicas: 1,
            max_replicas: 2,
            max_queue: MAX_QUEUE,
            theta: None,
        },
        TierSpec::fixed(Gpu::H100, 1, MAX_QUEUE),
    ]
}

fn spawn_fleet(specs: Vec<TierSpec>) -> (Arc<TieredFleet>, Arc<Metrics>) {
    let metrics = Metrics::new();
    let fleet = Arc::new(
        TieredFleet::spawn(
            staged() as Arc<dyn StageClassifier>,
            TieredFleetConfig { tiers: specs, batcher: batcher(), class_weights: None },
            Arc::clone(&metrics),
        )
        .unwrap(),
    );
    (fleet, metrics)
}

fn req(id: u64) -> Request {
    Request {
        id,
        features: vec![id as f32 * 0.61 - 7.0, 0.0, 0.0, 0.0],
        arrival_s: 0.0,
        class: abc_serve::types::Class::Standard,
    }
}

#[test]
fn routed_execution_is_byte_identical_to_monolithic() {
    // fast stages: this test is about answers, not capacity
    let fast = Arc::new(StagedSynthetic::new(
        SyntheticClassifier::new(DIM, LEVELS, Duration::ZERO, Duration::from_micros(40)),
        WEIGHTS.to_vec(),
    ));
    let fleet = Arc::new(
        TieredFleet::spawn(
            Arc::clone(&fast) as Arc<dyn StageClassifier>,
            TieredFleetConfig {
                tiers: vec![
                    TierSpec::fixed(Gpu::V100, 2, 256),
                    TierSpec::fixed(Gpu::A6000, 2, 256),
                    TierSpec::fixed(Gpu::H100, 1, 256),
                ],
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_micros(200),
                },
                class_weights: None,
            },
            Metrics::new(),
        )
        .unwrap(),
    );
    let n = 300usize;
    let mut feats = Vec::with_capacity(n * DIM);
    for id in 0..n as u64 {
        feats.extend_from_slice(&req(id).features);
    }
    // monolithic reference: one classify_batch over everything
    let want = fast.classify_batch(&feats, n).unwrap();
    // routed: concurrent submitters through the fleet (handoffs cross
    // pool batchers in arbitrary interleavings)
    let fleet_ref = &fleet;
    let got: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n as u64)
            .map(|id| s.spawn(move || fleet_ref.infer(req(id)).unwrap()))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut exits = vec![0usize; LEVELS];
    for v in &got {
        let w = &want[v.request_id as usize];
        assert_eq!(v.prediction, w.prediction, "id {}", v.request_id);
        assert_eq!(v.exit_tier, w.exit_level, "id {}", v.request_id);
        assert_eq!(v.tier_scores, w.scores, "id {}", v.request_id);
        exits[v.exit_tier - 1] += 1;
    }
    // exit fractions match the monolithic report exactly
    let mut want_exits = vec![0usize; LEVELS];
    for w in &want {
        want_exits[w.exit_level - 1] += 1;
    }
    assert_eq!(exits, want_exits);
    assert_eq!(fleet.metrics().counter("fleet_completed").get(), n as u64);
    assert_eq!(fleet.metrics().counter("fleet_shed").get(), 0);
    assert_eq!(fleet.total_outstanding(), 0);
}

#[test]
fn tiered_fleet_matches_monolithic_goodput_for_fewer_dollars() {
    let _serial = TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    // on-off bursts at 2x the monolithic pool's saturation point; n is
    // sized so the trace spans several on-windows (~2.2s wall) and the
    // interior drain at 400ms lands genuinely mid-run
    let burst_rps = 2.0 * mono_capacity_rps();
    let n = 4800;
    let trace = Arc::new(Trace::synth(
        Arrival::OnOff { rate: burst_rps, on_s: 0.4, off_s: 0.5 },
        n,
        DIM,
        37,
    ));
    // workers must exceed both targets' total admission capacity
    // (monolithic: 4x32 = 128) or the generator, not admission
    // control, becomes the bottleneck and nothing ever sheds
    let gen = LoadGen { workers: 192, class_mix: None };

    // ---- monolithic baseline: whole cascade on every replica, so
    // every machine must be the top-model GPU (H100, the PoolConfig
    // default) ----
    let mono_pool = Arc::new(ReplicaPool::spawn(
        Arc::new(inner()),
        PoolConfig {
            replicas: MONO_REPLICAS,
            max_queue: MAX_QUEUE,
            batcher: batcher(),
            ..PoolConfig::default()
        },
        Metrics::new(),
    ));
    let mono = gen.run(&mono_pool, Arc::clone(&trace), &Metrics::new()).unwrap();
    let mono_dollars = mono_pool.dollars();
    assert_eq!(mono_pool.gpu(), Gpu::H100);

    // ---- tiered: cheap GPUs up front, one expensive top pool ----
    let (fleet, metrics) = spawn_fleet(fleet_spec());
    // mid-run chaos: drain one of the interior tier's two replicas
    // while the burst is in flight, then re-provision it
    let drain_fleet = Arc::clone(&fleet);
    let churn = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(400));
        let drained = drain_fleet.tier(1).pool().drain(1);
        assert_eq!(drained.len(), 1, "interior drain refused");
        // let the drained replica finish its queue and retire
        for _ in 0..200 {
            drain_fleet.advance(Instant::now());
            if drain_fleet.tier(1).pool().counts().2 == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            drain_fleet.tier(1).pool().counts().2,
            0,
            "drained replica never retired"
        );
        // bring the tier back to strength for the rest of the run
        let re = drain_fleet.tier(1).pool().scale_up(1, Duration::ZERO);
        assert_eq!(re.len(), 1);
    });
    let tiered = gen.run(&fleet, Arc::clone(&trace), &Metrics::new()).unwrap();
    churn.join().unwrap();
    let tiered_dollars = fleet.dollars();

    // exact per-request accounting on both sides
    assert_eq!(mono.errors, 0, "{mono:?}");
    assert_eq!(tiered.errors, 0, "{tiered:?}");
    assert_eq!(mono.completed + mono.shed, n as u64, "{mono:?}");
    assert_eq!(tiered.completed + tiered.shed, n as u64, "{tiered:?}");
    // ...and the fleet's own books agree with the load generator's:
    // exactly-once across handoffs, the interior drain, and sheds at
    // any depth
    assert_eq!(metrics.counter("fleet_submitted").get(), n as u64);
    assert_eq!(metrics.counter("fleet_completed").get(), tiered.completed);
    assert_eq!(metrics.counter("fleet_shed").get(), tiered.shed);
    let exited: u64 = (0..LEVELS).map(|i| fleet.tier(i).exited()).sum();
    assert_eq!(exited, tiered.completed);
    assert_eq!(fleet.total_outstanding(), 0);
    // the drain genuinely cycled a replica
    assert!(
        fleet.tier(1).pool().metrics().counter("replicas_retired").get() >= 1,
        "interior tier never retired a replica"
    );
    // 2x saturation means the monolithic pool genuinely shed
    assert!(mono.shed > 0, "trace never saturated the baseline: {mono:?}");

    // headline (acceptance bar): goodput within 5% of the monolithic
    // pool -- the tiered fleet should in fact beat it, since most
    // requests exit on the cheap tiers -- at measurably fewer dollars
    assert!(
        tiered.completed as f64 >= 0.95 * mono.completed as f64,
        "tiered {} vs monolithic {} completed",
        tiered.completed,
        mono.completed
    );
    assert!(
        tiered_dollars < 0.75 * mono_dollars,
        "no rental win: tiered ${tiered_dollars:.6} vs monolithic \
         ${mono_dollars:.6}"
    );

    // telemetry: per-tier gauges + fleet dollars are published
    fleet.refresh_gauges();
    assert!(metrics.gauge("fleet_dollars").get() > 0.0);
    assert!(metrics.gauge("fleet_dollars_per_hour").get() > 0.0);
    let frac_sum: f64 = (0..LEVELS)
        .map(|i| metrics.gauge(&format!("tier_{i}_exit_frac")).get())
        .sum();
    assert!((frac_sum - 1.0).abs() < 0.05, "exit fracs sum to ~1: {frac_sum}");
}

/// The per-tier gear-shifting headline: under 2x-saturation on-off
/// load, a tiered fleet whose control loop walks per-tier theta rungs
/// (driven by each tier's downstream pool, where the deferral stream
/// lands) completes at least as much work as the fixed-gear tiered
/// fleet while spending no more fleet-dollars -- and the books stay
/// exactly-once across concurrent shift + scale actions in one run.
#[test]
fn per_tier_gear_shifting_beats_fixed_gears_at_no_more_dollars() {
    let _serial = TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    // longer on-windows than the other suites: theta relief compounds
    // over a burst, while the fixed fleet drowns at the top tier for
    // the whole window
    let burst_rps = 2.0 * mono_capacity_rps();
    let n = 4800;
    let trace = Arc::new(Trace::synth(
        Arrival::OnOff { rate: burst_rps, on_s: 0.5, off_s: 0.25 },
        n,
        DIM,
        43,
    ));
    let gen = LoadGen { workers: 192, class_mix: None };

    // ---- fixed gears: the PR-4 fleet shape, no control loop ----
    let (fixed_fleet, _) = spawn_fleet(vec![
        TierSpec::fixed(Gpu::V100, 2, MAX_QUEUE),
        TierSpec::fixed(Gpu::A6000, 2, MAX_QUEUE),
        TierSpec::fixed(Gpu::H100, 1, MAX_QUEUE),
    ]);
    let fixed = gen
        .run(&fixed_fleet, Arc::clone(&trace), &Metrics::new())
        .unwrap();
    let fixed_dollars = fixed_fleet.dollars();
    // at 2x saturation the fixed top tier genuinely drowns
    assert!(fixed.shed > 0, "baseline never saturated: {fixed:?}");

    // ---- geared: same ceilings, elastic floors, theta ladders on the
    // non-final tiers, budget pinned to the fixed fleet's burn rate so
    // the dollars bound is structural ----
    let stage = staged();
    let (fleet, metrics) = spawn_fleet(vec![
        TierSpec::elastic(Gpu::V100, 1, 2, MAX_QUEUE),
        TierSpec::elastic(Gpu::A6000, 1, 2, MAX_QUEUE),
        TierSpec::fixed(Gpu::H100, 1, MAX_QUEUE),
    ]);
    let rungs = vec![
        TierRung { theta: None, max_batch: MAX_BATCH },
        TierRung { theta: Some(0.6), max_batch: MAX_BATCH },
        TierRung { theta: Some(0.3), max_batch: MAX_BATCH },
    ];
    let fixed_burn = 2.0 * 0.50 + 2.0 * 0.80 + 2.49;
    let tiers: Vec<TierControl> = (0..LEVELS)
        .map(|i| TierControl {
            per_replica_rps: stage.stage_capacity_rps(i, MAX_BATCH),
            scale: (i < 2).then(|| ScaleConfig {
                min_replicas: 1,
                max_replicas: 2,
                warmup: Duration::ZERO,
                ..ScaleConfig::default()
            }),
            rungs: if i + 1 < LEVELS { rungs.clone() } else { vec![] },
        })
        .collect();
    let mut control = ControlLoop::spawn(
        Arc::clone(&fleet) as Arc<dyn ControlTarget>,
        ControlConfig::tiered(
            tiers,
            ControllerConfig {
                sample_every: Duration::from_millis(10),
                dwell: Duration::from_millis(80),
                ..ControllerConfig::default()
            },
            fixed_burn,
        ),
    );
    let geared = gen.run(&fleet, Arc::clone(&trace), &Metrics::new()).unwrap();
    let geared_dollars = fleet.dollars();

    // exactly-once on both sides, and the fleet's own books agree with
    // the generator's across concurrent shift + scale actions
    assert_eq!(fixed.errors, 0, "{fixed:?}");
    assert_eq!(geared.errors, 0, "{geared:?}");
    assert_eq!(fixed.completed + fixed.shed, n as u64, "{fixed:?}");
    assert_eq!(geared.completed + geared.shed, n as u64, "{geared:?}");
    assert_eq!(metrics.counter("fleet_submitted").get(), n as u64);
    assert_eq!(metrics.counter("fleet_completed").get(), geared.completed);
    assert_eq!(metrics.counter("fleet_shed").get(), geared.shed);
    let exited: u64 = (0..LEVELS).map(|i| fleet.tier(i).exited()).sum();
    assert_eq!(exited, geared.completed);
    assert_eq!(fleet.total_outstanding(), 0);

    // both decider families genuinely acted in the same run
    assert!(
        metrics.counter("gear_shift_down").get() > 0,
        "no per-tier downshift; events: {}",
        metrics.events().to_jsonl()
    );
    assert!(
        metrics.counter("scale_up_total").get() > 0,
        "never scaled up; metrics: {:?}",
        metrics.snapshot()
    );
    let events = metrics.events().snapshot();
    assert!(
        events.iter().any(|e| {
            e.kind == abc_serve::metrics::EventKind::Shift
                && e.decider == "gear"
                && e.tier < 2
        }),
        "shift events must attribute the gear decider + tier index"
    );
    assert!(
        events
            .iter()
            .any(|e| e.kind == abc_serve::metrics::EventKind::Scale),
        "no scale events logged"
    );

    // headline (acceptance bar): at least the fixed-gear goodput, at
    // no more fleet-dollars
    assert!(
        geared.completed >= fixed.completed,
        "geared {} < fixed {} completed",
        geared.completed,
        fixed.completed
    );
    assert!(
        geared_dollars <= fixed_dollars,
        "geared ${geared_dollars:.6} > fixed ${fixed_dollars:.6}"
    );

    // after the load ends the ladder restores the calibrated policy
    // and the fleet drains back to its floors
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let restored =
            fleet.tier_theta(0).is_none() && fleet.tier_theta(1).is_none();
        let floors = fleet.replicas_per_tier() == vec![1, 1, 1];
        if restored && floors {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "stuck at thetas {:?}/{:?}, replicas {:?}; events: {}",
            fleet.tier_theta(0),
            fleet.tier_theta(1),
            fleet.replicas_per_tier(),
            metrics.events().to_jsonl()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(metrics.counter("gear_shift_up").get() > 0, "never restored");
    control.stop();
}

#[test]
fn tiered_autoscaler_scales_tiers_independently_and_drains_back() {
    let _serial = TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let stage = staged();
    // every tier elastic 1..=3, starting at the floor
    let specs: Vec<TierSpec> = [Gpu::V100, Gpu::A6000, Gpu::H100]
        .iter()
        .map(|&gpu| TierSpec::elastic(gpu, 1, 3, MAX_QUEUE))
        .collect();
    let (fleet, metrics) = spawn_fleet(specs);
    // the unified control plane, scale deciders only (no theta rungs):
    // the TieredAutoscaler-equivalent shape
    let tiers: Vec<TierControl> = (0..LEVELS)
        .map(|i| TierControl {
            per_replica_rps: stage.stage_capacity_rps(i, MAX_BATCH),
            scale: Some(ScaleConfig {
                min_replicas: 1,
                max_replicas: 3,
                warmup: Duration::ZERO,
                ..ScaleConfig::default()
            }),
            rungs: vec![],
        })
        .collect();
    let mut autoscaler = ControlLoop::spawn(
        Arc::clone(&fleet) as Arc<dyn ControlTarget>,
        ControlConfig::tiered(
            tiers,
            ControllerConfig {
                sample_every: Duration::from_millis(10),
                dwell: Duration::from_millis(80),
                ..ControllerConfig::default()
            },
            0.0,
        ),
    );
    // bursts hot enough that every single-replica tier must grow
    // (tier arrivals thin with depth, but 2x monolithic saturation
    // overloads even the fast front tier's floor)
    let burst_rps = 2.0 * mono_capacity_rps();
    let n = 3200;
    let trace = Arc::new(Trace::synth(
        Arrival::OnOff { rate: burst_rps, on_s: 0.4, off_s: 0.5 },
        n,
        DIM,
        41,
    ));
    let report = LoadGen { workers: 128, class_mix: None }
        .run(&fleet, trace, &Metrics::new())
        .unwrap();
    assert_eq!(report.errors, 0, "{report:?}");
    assert_eq!(report.completed + report.shed, n as u64);
    // the autoscaler scaled up during the bursts...
    assert!(
        metrics.counter("scale_up_total").get() > 0,
        "never scaled up; metrics: {:?}",
        metrics.snapshot()
    );
    // ...and recorded per-tier decisions (tier index in the gear slots)
    let events = metrics.events().snapshot();
    assert!(
        events
            .iter()
            .any(|e| e.kind == abc_serve::metrics::EventKind::Scale),
        "no scale events logged"
    );
    // after the load ends every tier drains back to its floor
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let per_tier = fleet.replicas_per_tier();
        let settled = (0..LEVELS).all(|i| {
            let (w, _, d) = fleet.tier(i).pool().counts();
            w == 0 && d == 0
        }) && per_tier == vec![1; LEVELS];
        if settled {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "fleet stuck at {:?}; events: {}",
            fleet.replicas_per_tier(),
            metrics.events().to_jsonl()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(
        metrics.counter("scale_down_total").get() > 0,
        "never scaled down"
    );
    autoscaler.stop();
    assert_eq!(fleet.total_outstanding(), 0);
}
