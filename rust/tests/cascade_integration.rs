//! Integration: the full calibrated cascade over real artifacts.
//!
//! The core paper claims as executable assertions:
//! * drop-in property (Prop 4.1.1): cascade accuracy >= top-tier-ensemble
//!   accuracy - epsilon (we use the manifest's recorded accuracy);
//! * agreement kernel (L1, on-device) == host twin (coordinator::agreement);
//! * deferral monotonicity in theta;
//! * exit fractions form a distribution and tier-1 handles a nontrivial
//!   share on an easy suite.

use std::sync::Arc;

use abc_serve::calib;
use abc_serve::coordinator::agreement::agree_logits;
use abc_serve::coordinator::cascade::Cascade;
use abc_serve::coordinator::deferral::{DeferralPolicy, TierRule};
use abc_serve::runtime::engine::Engine;
use abc_serve::types::RuleKind;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn setup(suite: &str) -> Option<(Manifest, Arc<SuiteRuntime>)> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let manifest = Manifest::load(root).unwrap();
    let engine = Arc::new(Engine::cpu().unwrap());
    let rt = Arc::new(SuiteRuntime::load(engine, &manifest, suite, false).unwrap());
    Some((manifest, rt))
}

#[test]
fn drop_in_property_holds() {
    let Some((manifest, rt)) = setup("synth-cifar10") else { return };
    let val = rt.dataset(&manifest, "val").unwrap();
    let test = rt.dataset(&manifest, "test").unwrap();
    let epsilon = 0.05;
    let cal = calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 100, epsilon).unwrap();
    let cascade = Cascade::new(rt.tiers.clone(), cal.policy.clone());
    let (_, report) = cascade.evaluate(&test.x, &test.y, test.n).unwrap();

    let top_acc = rt.suite.top_tier().test_acc_ensemble;
    // Prop 4.1: R(cascade) <= R(top) + eps  (+ binomial slack on 10k samples)
    assert!(
        report.accuracy >= top_acc - epsilon - 0.02,
        "cascade acc {:.4} vs top tier {top_acc:.4} (eps {epsilon})",
        report.accuracy
    );
    // exit fractions are a distribution
    let total: f64 = report.exit_fractions.iter().sum();
    assert!((total - 1.0).abs() < 1e-9);
    // the cheap tier must matter (else the suite is miscalibrated)
    assert!(
        report.exit_fractions[0] > 0.3,
        "tier-1 exit fraction too small: {:?}",
        report.exit_fractions
    );
}

#[test]
fn cascade_saves_flops_vs_top_tier() {
    let Some((manifest, rt)) = setup("synth-sst2") else { return };
    let val = rt.dataset(&manifest, "val").unwrap();
    let test = rt.dataset(&manifest, "test").unwrap();
    let cal = calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 100, 0.05).unwrap();
    let cascade = Cascade::new(rt.tiers.clone(), cal.policy.clone());
    let (_, report) = cascade.evaluate(&test.x, &test.y, test.n).unwrap();
    // mean per-sample member-FLOPs under rho=1
    let mut reach = 1.0;
    let mut flops = 0.0;
    for (tier, &exit) in rt.suite.tiers.iter().zip(&report.exit_fractions) {
        flops += reach * tier.flops_per_sample_member as f64;
        reach -= exit;
    }
    let top = rt.suite.top_tier().flops_per_sample_member as f64;
    assert!(
        flops < top,
        "cascade mean flops {flops:.0} not below top tier {top:.0} \
         (exits {:?})",
        report.exit_fractions
    );
}

#[test]
fn kernel_agreement_matches_host_twin() {
    let Some((manifest, rt)) = setup("synth-swag") else { return };
    let test = rt.dataset(&manifest, "test").unwrap();
    let tier = &rt.tiers[2];
    let n = 64;
    let (outs, logits) = tier
        .run_with_logits(&test.x[..n * test.dim], n)
        .unwrap();
    let c = rt.suite.classes;
    let k = tier.k;
    let mut sample_logits = vec![0.0f32; k * c];
    for i in 0..n {
        for m in 0..k {
            let off = (m * n + i) * c;
            sample_logits[m * c..(m + 1) * c].copy_from_slice(&logits[off..off + c]);
        }
        let host = agree_logits(&sample_logits, k, c);
        assert_eq!(host.majority, outs[i].majority, "sample {i} majority");
        assert!((host.vote_frac - outs[i].vote_frac).abs() < 1e-5);
        assert!((host.mean_score - outs[i].mean_score).abs() < 1e-4);
    }
}

#[test]
fn deferral_monotone_in_theta() {
    let Some((manifest, rt)) = setup("synth-twitterfin") else { return };
    let test = rt.dataset(&manifest, "test").unwrap();
    let test = test.slice(0, 800);
    let mut last_exit1 = 2.0;
    for theta in [0.0f32, 0.5, 0.8, 0.95, 1.1] {
        let policy = DeferralPolicy::new(
            vec![TierRule { rule: RuleKind::MeanScore, theta }; rt.tiers.len() - 1],
            rt.tiers.len(),
        );
        let cascade = Cascade::new(rt.tiers.clone(), policy);
        let (_, report) = cascade.evaluate(&test.x, &test.y, test.n).unwrap();
        assert!(
            report.exit_fractions[0] <= last_exit1 + 1e-9,
            "tier-1 exits must shrink as theta grows"
        );
        last_exit1 = report.exit_fractions[0];
    }
    // theta > 1 defers everything
    assert_eq!(last_exit1, 0.0);
}

#[test]
fn staged_execution_matches_an_independent_reference_sieve() {
    // The tiered fleet routes per-tier stages between pools, and
    // `Cascade::classify_batch_with` drives the SAME stages in-process.
    // Both must reproduce the original inline sieve exactly -- this
    // test IS that original algorithm, hand-rolled over the tier
    // executables + policy, compared byte-for-byte (preds, exit levels,
    // scores, exit fractions) against the stage-wise path, with and
    // without gear theta overrides.
    let Some((manifest, rt)) = setup("synth-cifar10") else { return };
    let test = rt.dataset(&manifest, "test").unwrap();
    let test = test.slice(0, 400);
    let policy = DeferralPolicy::new(
        vec![TierRule { rule: RuleKind::MeanScore, theta: 0.8 }; rt.tiers.len() - 1],
        rt.tiers.len(),
    );
    let cascade = Cascade::new(rt.tiers.clone(), policy.clone());
    let thetas: Vec<Option<Vec<f32>>> = vec![
        None,
        Some(vec![0.6; rt.tiers.len() - 1]),
        Some(vec![1.1; rt.tiers.len() - 1]), // defer-everything override
    ];
    for over in thetas {
        let got = cascade
            .classify_batch_with(&test.x, test.n, over.as_deref())
            .unwrap();
        // reference: the pre-tiered inline sieve
        let dim = rt.tiers[0].dim;
        let mut active: Vec<usize> = (0..test.n).collect();
        let mut want: Vec<Option<(u32, usize, Vec<f32>)>> = vec![None; test.n];
        let mut scores: Vec<Vec<f32>> = vec![Vec::new(); test.n];
        for (level0, tier) in rt.tiers.iter().enumerate() {
            if active.is_empty() {
                break;
            }
            let mut sub = Vec::with_capacity(active.len() * dim);
            for &i in &active {
                sub.extend_from_slice(&test.x[i * dim..(i + 1) * dim]);
            }
            let outs = tier.run(&sub, active.len()).unwrap();
            let last = level0 + 1 == rt.tiers.len();
            let rule = over
                .as_ref()
                .and_then(|ts| ts.get(level0))
                .filter(|_| !last)
                .map(|&theta| TierRule { rule: RuleKind::MeanScore, theta });
            let mut still = Vec::new();
            for (j, &i) in active.iter().enumerate() {
                scores[i].push(policy.score(level0, &outs[j]));
                let decision = match &rule {
                    Some(r) => r.decide(&outs[j]),
                    None => policy.decide(level0, &outs[j]),
                };
                if decision == abc_serve::types::Decision::Accept {
                    want[i] = Some((
                        outs[j].majority,
                        level0 + 1,
                        std::mem::take(&mut scores[i]),
                    ));
                } else {
                    still.push(i);
                }
            }
            active = still;
        }
        assert!(active.is_empty());
        for (i, g) in got.iter().enumerate() {
            let (pred, exit, sc) = want[i].clone().unwrap();
            assert_eq!(g.prediction, pred, "sample {i}");
            assert_eq!(g.exit_level, exit, "sample {i}");
            assert_eq!(g.scores, sc, "sample {i}");
        }
    }
}

#[test]
fn accuracy_improvement_shows_up_somewhere() {
    // Paper §5.1.1: ABC often IMPROVES accuracy over the best single
    // model.  Check the cascade matches-or-beats the top tier's member-0
    // single model on at least half the suites.
    let Some((manifest, _)) = setup("synth-cifar10") else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let mut wins = 0;
    let mut total = 0;
    for suite in ["synth-cifar10", "synth-sst2", "synth-twitterfin", "synth-swag"] {
        let rt =
            Arc::new(SuiteRuntime::load(Arc::clone(&engine), &manifest, suite, true).unwrap());
        let val = rt.dataset(&manifest, "val").unwrap();
        let test = rt.dataset(&manifest, "test").unwrap();
        let cal =
            calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 100, 0.05).unwrap();
        let cascade = Cascade::new(rt.tiers.clone(), cal.policy.clone());
        let (_, report) = cascade.evaluate(&test.x, &test.y, test.n).unwrap();
        let outs = rt.singles.last().unwrap().run_single(&test.x, test.n).unwrap();
        let single_acc = outs
            .iter()
            .zip(&test.y)
            .filter(|(o, &y)| o.pred == y)
            .count() as f64
            / test.n as f64;
        total += 1;
        if report.accuracy >= single_acc {
            wins += 1;
        }
    }
    assert!(wins * 2 >= total, "ABC beat the single model on only {wins}/{total} suites");
}

#[test]
fn calibration_selection_rates_monotone_in_epsilon() {
    let Some((manifest, rt)) = setup("synth-imagenet") else { return };
    let val = rt.dataset(&manifest, "val").unwrap();
    let mut last = -1.0;
    for eps in [0.01, 0.03, 0.05, 0.10] {
        let cal =
            calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 200, eps).unwrap();
        let sel = cal.estimates[0].selection_rate;
        assert!(sel >= last, "selection not monotone in epsilon");
        last = sel;
    }
}
