//! Property tests (minicheck) on coordinator invariants -- no PJRT
//! needed: these exercise the pure logic (agreement, deferral, batcher,
//! cost model, calibration) under randomized inputs with shrinking.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use abc_serve::calib::threshold::{estimate_theta, evaluate_theta, CalPoint};
use abc_serve::coordinator::agreement::{agree_logits, agree_votes};
use abc_serve::coordinator::batcher::{Batcher, BatcherConfig, Item};
use abc_serve::cost::model::{
    cost_from_exits, two_level_relative_cost, worst_case_bound,
};
use abc_serve::prop_assert;
use abc_serve::types::Parallelism;
use abc_serve::util::minicheck::check;
use abc_serve::util::rng::Rng;

// ---------------------------------------------------------------------
// agreement
// ---------------------------------------------------------------------

#[test]
fn prop_agreement_majority_is_a_member_prediction() {
    check(
        101,
        300,
        |r| {
            let k = 1 + r.below(6);
            let c = 2 + r.below(10);
            let logits: Vec<f64> =
                (0..k * c).map(|_| r.f64() * 8.0 - 4.0).collect();
            (vec![k, c], logits)
        },
        |(kc, logits)| {
            let (k, c) = (kc[0], kc[1]);
            let lg: Vec<f32> = logits.iter().map(|&x| x as f32).collect();
            let out = agree_logits(&lg, k, c);
            // the majority label must be some member's argmax
            let mut found = false;
            for m in 0..k {
                let row = &lg[m * c..(m + 1) * c];
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if argmax as u32 == out.majority {
                    found = true;
                }
            }
            prop_assert!(found, "majority {} not any member's argmax", out.majority);
            prop_assert!(
                out.vote_frac >= 1.0 / k as f32 - 1e-6,
                "vote frac below 1/k"
            );
            prop_assert!(out.vote_frac <= 1.0 + 1e-6, "vote frac above 1");
            prop_assert!(
                out.mean_score > 0.0 && out.mean_score <= 1.0 + 1e-6,
                "score out of range: {}",
                out.mean_score
            );
            Ok(())
        },
    );
}

#[test]
fn prop_vote_majority_has_max_count() {
    check(
        102,
        500,
        |r| (0..1 + r.below(9)).map(|_| r.below(6) as u64).collect::<Vec<u64>>(),
        |answers| {
            let ans32: Vec<u32> = answers.iter().map(|&a| a as u32).collect();
            let (maj, frac) = agree_votes(&ans32);
            let count_of = |x: u32| ans32.iter().filter(|&&a| a == x).count();
            let maj_count = count_of(maj);
            for &a in &ans32 {
                prop_assert!(
                    count_of(a) <= maj_count,
                    "answer {a} outvotes majority {maj}"
                );
            }
            prop_assert!(
                (frac - maj_count as f32 / ans32.len() as f32).abs() < 1e-6,
                "frac mismatch"
            );
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// batcher: conservation + order, randomized configs and pacing
// ---------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_and_orders() {
    check(
        103,
        12,
        |r| {
            let max_batch = 1 + r.below(16);
            let n_items = r.below(120);
            let pace_us = r.below(300);
            vec![max_batch, n_items, pace_us]
        },
        |cfg| {
            let (max_batch, n_items, pace_us) = (cfg[0], cfg[1], cfg[2]);
            let seen = Arc::new(Mutex::new(Vec::new()));
            let violations = Arc::new(Mutex::new(Vec::<String>::new()));
            {
                let seen2 = Arc::clone(&seen);
                let viol = Arc::clone(&violations);
                let b = Batcher::spawn(
                    BatcherConfig {
                        max_batch,
                        max_wait: Duration::from_micros(400),
                    },
                    move |batch: Vec<Item<usize>>| {
                        if batch.is_empty() {
                            viol.lock().unwrap().push("empty flush".into());
                        }
                        if batch.len() > max_batch {
                            viol.lock().unwrap().push("flush > max_batch".into());
                        }
                        seen2
                            .lock()
                            .unwrap()
                            .extend(batch.into_iter().map(|i| i.payload));
                    },
                );
                for i in 0..n_items {
                    b.push(i).unwrap();
                    if pace_us > 0 && i % 7 == 0 {
                        std::thread::sleep(Duration::from_micros(pace_us as u64));
                    }
                }
            } // drop drains
            let got = seen.lock().unwrap().clone();
            let viols = violations.lock().unwrap().clone();
            prop_assert!(viols.is_empty(), "flush violations: {viols:?}");
            prop_assert!(
                got == (0..n_items).collect::<Vec<_>>(),
                "conservation/order violated: got {} of {} items",
                got.len(),
                n_items
            );
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// cost model
// ---------------------------------------------------------------------

#[test]
fn prop_cost_bounded_by_worst_case() {
    check(
        104,
        400,
        |r| {
            let k = 1 + r.below(6);
            vec![k as f64, r.f64(), r.f64(), r.f64()]
        },
        |v| {
            let (k, gamma, p_defer, rho) = (v[0] as usize, v[1], v[2], v[3]);
            let c = two_level_relative_cost(k, gamma, Parallelism(rho), p_defer);
            let wc = worst_case_bound(&[(k, gamma), (1, 1.0)]);
            prop_assert!(c <= wc + 1e-9, "cost {c} above worst case {wc}");
            prop_assert!(c >= 0.0, "negative cost");
            // cost at rho=1 is a lower bound over rho
            let c1 = two_level_relative_cost(k, gamma, Parallelism(1.0), p_defer);
            prop_assert!(c1 <= c + 1e-12, "rho=1 not cheapest");
            Ok(())
        },
    );
}

#[test]
fn prop_cost_from_exits_between_extremes() {
    check(
        105,
        300,
        |r| {
            let n = 2 + r.below(3);
            let mut exits: Vec<f64> = (0..n).map(|_| r.f64() + 1e-6).collect();
            let total: f64 = exits.iter().sum();
            for e in &mut exits {
                *e /= total;
            }
            exits
        },
        |exits| {
            let n = exits.len();
            if n < 2 {
                return Ok(()); // shrinker may produce degenerate vectors
            }
            let total: f64 = exits.iter().sum();
            if (total - 1.0).abs() > 1e-6 || exits.iter().any(|&e| e < 0.0) {
                return Ok(()); // shrunk out of the valid domain
            }
            let levels: Vec<(usize, f64)> = (0..n)
                .map(|i| (3usize, 10f64.powi(i as i32 - (n as i32 - 1))))
                .collect();
            let c = cost_from_exits(&levels, exits, Parallelism(1.0));
            prop_assert!(c >= levels[0].1 - 1e-12, "below first-level cost");
            let all: f64 = levels.iter().map(|(_, g)| g).sum();
            prop_assert!(c <= all + 1e-9, "above pay-everything cost");
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// calibration
// ---------------------------------------------------------------------

#[test]
fn prop_estimated_theta_meets_tolerance_in_sample() {
    check(
        106,
        200,
        |r| {
            let n = 20 + r.below(400);
            (0..n)
                .map(|_| {
                    let score = r.f64();
                    let correct = r.bool(0.3 + 0.6 * score);
                    (score, if correct { 1.0 } else { 0.0 })
                })
                .collect::<Vec<(f64, f64)>>()
        },
        |data| {
            if data.is_empty() {
                return Ok(());
            }
            let points: Vec<CalPoint> = data
                .iter()
                .map(|&(s, c)| CalPoint { score: s as f32, correct: c > 0.5 })
                .collect();
            for eps in [0.0, 0.02, 0.05, 0.2] {
                let est = estimate_theta(&points, eps);
                // the IN-SAMPLE failure at the estimated theta must meet eps
                let (fail, sel) = evaluate_theta(&points, est.theta);
                prop_assert!(
                    fail <= eps + 1e-9,
                    "failure {fail} exceeds eps {eps}"
                );
                prop_assert!(
                    (sel - est.selection_rate).abs() < 1e-9,
                    "selection rate inconsistent: {sel} vs {}",
                    est.selection_rate
                );
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// threadpool scope_map under random shapes
// ---------------------------------------------------------------------

#[test]
fn prop_scope_map_is_identity_preserving() {
    let pool = Arc::new(abc_serve::util::threadpool::ThreadPool::new(4));
    check(
        107,
        30,
        |r: &mut Rng| (0..r.below(200)).map(|i| i as u64).collect::<Vec<u64>>(),
        move |items| {
            let out = pool.scope_map(items.clone(), |x| x * 3 + 1);
            prop_assert!(
                out == items.iter().map(|x| x * 3 + 1).collect::<Vec<_>>(),
                "scope_map broke order"
            );
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// stage-wise execution equivalence (tiered-fleet tentpole)
// ---------------------------------------------------------------------

#[test]
fn prop_staged_sieve_matches_monolithic_classify_batch() {
    use abc_serve::coordinator::cascade::{classify_batch_staged, BatchClassifier};
    use abc_serve::trafficgen::{StagedSynthetic, SyntheticClassifier};
    check(
        109,
        120,
        |r: &mut Rng| {
            let dim = 1 + r.below(4);
            let levels = 1 + r.below(4);
            let n = r.below(50);
            let weights: Vec<f64> = (0..levels).map(|_| r.f64()).collect();
            let features: Vec<f64> =
                (0..n * dim).map(|_| r.f64() * 10.0 - 5.0).collect();
            ((vec![dim, levels, n], weights), features)
        },
        |((shape, weights), features)| {
            // shrinking may desynchronise the pieces; skip invalid shapes
            if shape.len() != 3 {
                return Ok(());
            }
            let (dim, levels, n) = (shape[0], shape[1], shape[2]);
            if dim == 0
                || levels == 0
                || weights.len() != levels
                || features.len() != n * dim
            {
                return Ok(());
            }
            let feats: Vec<f32> = features.iter().map(|&x| x as f32).collect();
            let inner = SyntheticClassifier::new(
                dim,
                levels,
                Duration::ZERO,
                Duration::ZERO,
            );
            let staged = StagedSynthetic::new(inner.clone(), weights.clone());
            // monolithic execution vs the stage-wise sieve driver: the
            // tiered fleet routes the SAME stages between pools, so this
            // equivalence is what makes `--tiered` answer-preserving
            let mono = inner.classify_batch(&feats, n).map_err(|e| e.to_string())?;
            let st = classify_batch_staged(&staged, &feats, n, None)
                .map_err(|e| e.to_string())?;
            prop_assert!(mono.len() == st.len(), "length mismatch");
            for (i, (a, b)) in mono.iter().zip(&st).enumerate() {
                prop_assert!(a.prediction == b.prediction, "pred differs at {i}");
                prop_assert!(a.exit_level == b.exit_level, "exit differs at {i}");
                prop_assert!(a.scores == b.scores, "scores differ at {i}");
            }
            Ok(())
        },
    );
}
