//! Integration: the SLO observatory + weighted-fair admission -- no
//! PJRT artifacts needed (synthetic backend).
//!
//! Covers the multi-tenant claims the subsystem exists for:
//! * **premium protection**: under a 2x-saturation burst dominated by
//!   batch traffic, weighted-fair admission keeps the premium class's
//!   SLO attainment high while plain FIFO admission (no class weights)
//!   sheds premium work indiscriminately and drops below the goal;
//! * **work conservation**: protecting premium costs little aggregate
//!   goodput versus FIFO;
//! * **exactly-once books**: per class, `submitted == completed +
//!   shed`, the class ledgers sum to the run totals, and the class mix
//!   lands in exact proportions;
//! * the same identities hold through the tiered fleet's routed path.
//!
//! Timing margins follow loadgen_integration.rs: the synthetic
//! classifier's sleep-based service time is a *lower* bound on real
//! elapsed time, so a slow CI machine only lowers capacity.  The
//! attainment assertions lean on SHED accounting (class-blind FIFO
//! sheds ~half of every class at 2x overload) rather than tight latency
//! targets, and the premium latency target carries a ~30x margin over
//! the nominal full-queue drain time.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::StageClassifier;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::coordinator::router::{TierSpec, TieredFleet, TieredFleetConfig};
use abc_serve::cost::rental::Gpu;
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::obs::slo::{SloConfig, SloObservatory, SloStatus};
use abc_serve::trafficgen::{
    LoadGen, LoadReport, StagedSynthetic, SyntheticClassifier, Trace,
};
use abc_serve::types::Class;

const DIM: usize = 4;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 64;
/// 2x-saturation burst mix: batch dominates the wire, premium is a
/// sliver (so premium stays far under its weighted share even when a
/// slow host halves real capacity).
const MIX: [f64; Class::COUNT] = [0.1, 0.1, 0.8];
/// Premium gets a large queue share, batch a sliver -- the quota, not
/// tier capacity, is what protects premium under the batch flood.
const WEIGHTS: [f64; Class::COUNT] = [0.8, 0.15, 0.05];
const N: usize = 2000;

/// The saturation tests reason about wall-clock capacity; run them one
/// at a time so they don't contend for cores with each other.
static TIMING_LOCK: Mutex<()> = Mutex::new(());

fn timing_guard() -> std::sync::MutexGuard<'static, ()> {
    TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// 2ms per row, no fixed cost, batches of 8: one replica sustains
/// ~500 rows/s regardless of host speed (sleep only overshoots).
fn classifier() -> Arc<SyntheticClassifier> {
    Arc::new(SyntheticClassifier::new(
        DIM,
        3,
        Duration::ZERO,
        Duration::from_millis(2),
    ))
}

/// Targets generous enough that completions practically always land
/// in-SLO: the fair-vs-FIFO attainment gap below is driven by SHEDS
/// (which count as misses), the part a slow host cannot invert.
fn slo_cfg() -> SloConfig {
    SloConfig { targets_s: [2.0, 4.0, 10.0], ..SloConfig::default() }
}

fn slo_pool(
    weights: Option<[f64; Class::COUNT]>,
) -> (Arc<ReplicaPool>, Arc<SloObservatory>) {
    let metrics = Metrics::new();
    let pool = Arc::new(ReplicaPool::spawn(
        classifier(),
        PoolConfig {
            replicas: 1,
            max_queue: MAX_QUEUE,
            batcher: BatcherConfig {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_millis(1),
            },
            class_weights: weights,
            ..PoolConfig::default()
        },
        Arc::clone(&metrics),
    ));
    let slo = SloObservatory::new(slo_cfg(), &metrics);
    pool.attach_slo(Arc::clone(&slo));
    (pool, slo)
}

/// One 2x-saturation run of the mixed-class trace; returns the load
/// report and the per-class books.
fn run_burst(
    weights: Option<[f64; Class::COUNT]>,
) -> (LoadReport, Vec<SloStatus>, Arc<ReplicaPool>) {
    let (pool, slo) = slo_pool(weights);
    let offered = 2.0 * classifier().capacity_rps(MAX_BATCH);
    let trace = Arc::new(Trace::synth(
        Arrival::Uniform { rate: offered },
        N,
        DIM,
        23,
    ));
    // workers must exceed the queue capacity (1x64) so admission
    // control, not the generator, is the bottleneck
    let report = LoadGen { workers: 192, class_mix: Some(MIX) }
        .run(&pool, trace, &Metrics::new())
        .expect("burst run");
    (report, slo.statuses(), pool)
}

fn attainment(statuses: &[SloStatus], class: Class) -> f64 {
    let s = &statuses[class.index()];
    assert_eq!(s.class, class);
    s.attainment
}

#[test]
fn weighted_fair_admission_protects_premium_under_batch_burst() {
    let _serial = timing_guard();

    // ---- FIFO baseline: class-blind admission sheds everyone ----
    let (fifo_report, fifo, _) = run_burst(None);
    // 2x overload genuinely saturated the pool
    assert!(fifo_report.shed > 0, "FIFO at 2x capacity never shed: {fifo_report:?}");
    assert_eq!(fifo_report.errors, 0, "{fifo_report:?}");
    let fifo_premium = attainment(&fifo, Class::Premium);
    assert!(
        fifo_premium < 0.95,
        "class-blind FIFO should shed premium below the goal at 2x \
         overload, got attainment {fifo_premium:.3}"
    );

    // ---- weighted-fair: premium rides inside its protected share ----
    let (fair_report, fair, pool) = run_burst(Some(WEIGHTS));
    assert_eq!(fair_report.errors, 0, "{fair_report:?}");
    let fair_premium = attainment(&fair, Class::Premium);
    assert!(
        fair_premium >= 0.95,
        "weighted-fair admission should hold premium attainment at the \
         goal under a batch burst, got {fair_premium:.3} \
         (FIFO: {fifo_premium:.3})"
    );
    // the batch flood is what got clipped, not the protected classes
    let fair_batch = &fair[Class::Batch.index()];
    assert!(
        fair_batch.shed > 0,
        "the 2x batch flood must be the class that sheds: {fair_batch:?}"
    );
    // work conservation: protecting premium is nearly free in aggregate
    assert!(
        fair_report.completed as f64 >= 0.95 * fifo_report.completed as f64,
        "weighted-fair goodput fell more than 5% below FIFO: \
         fair {} vs FIFO {}",
        fair_report.completed,
        fifo_report.completed
    );
    // quota units all returned once the verdicts drained
    for class in Class::ALL {
        assert_eq!(
            pool.class_outstanding(class),
            0,
            "{} quota units leaked",
            class.name()
        );
    }
}

#[test]
fn class_books_are_exactly_once_and_the_mix_is_exact() {
    let _serial = timing_guard();
    let (report, statuses, _) = run_burst(Some(WEIGHTS));

    // the 37-step wheel deals whole blocks of 100: 2000 requests at
    // [0.1, 0.1, 0.8] is exactly 200/200/1600 submitted
    let expect = [200u64, 200, 1600];
    let mut completed = 0u64;
    let mut shed = 0u64;
    for class in Class::ALL {
        let s = &statuses[class.index()];
        assert_eq!(
            s.submitted,
            expect[class.index()],
            "{} mix is off: {s:?}",
            class.name()
        );
        // exactly-once: every submitted request terminates exactly once
        assert_eq!(
            s.submitted,
            s.completed + s.shed,
            "{} books leak: {s:?}",
            class.name()
        );
        assert_eq!(s.deferred, 0, "monolithic pool never defers: {s:?}");
        completed += s.completed;
        shed += s.shed;
    }
    // the class ledgers sum to the run totals
    assert_eq!(completed, report.completed, "{report:?}");
    assert_eq!(shed, report.shed, "{report:?}");
    assert_eq!(completed + shed, N as u64);
}

#[test]
fn fleet_class_ledgers_hold_through_the_routed_path() {
    let _serial = timing_guard();
    // small staged fleet: deferral exercises the per-hop class books
    let stage = Arc::new(StagedSynthetic::new(
        SyntheticClassifier::new(DIM, 3, Duration::ZERO, Duration::from_micros(200)),
        vec![0.3, 0.3, 0.4],
    ));
    let metrics = Metrics::new();
    let fleet = Arc::new(
        TieredFleet::spawn_with_slo(
            stage as Arc<dyn StageClassifier>,
            TieredFleetConfig {
                tiers: vec![
                    TierSpec::fixed(Gpu::V100, 1, MAX_QUEUE),
                    TierSpec::fixed(Gpu::A6000, 1, MAX_QUEUE),
                    TierSpec::fixed(Gpu::H100, 1, MAX_QUEUE),
                ],
                batcher: BatcherConfig {
                    max_batch: MAX_BATCH,
                    max_wait: Duration::from_millis(1),
                },
                class_weights: Some(WEIGHTS),
            },
            Arc::clone(&metrics),
            None,
            None,
            Some(slo_cfg()),
        )
        .expect("fleet spawn"),
    );
    let n = 400usize;
    let trace = Arc::new(Trace::synth(
        Arrival::Poisson { rate: 800.0 },
        n,
        DIM,
        29,
    ));
    let report = LoadGen { workers: 64, class_mix: Some(MIX) }
        .run(&fleet, trace, &Metrics::new())
        .expect("fleet run");
    assert_eq!(report.errors, 0, "{report:?}");

    let slo = fleet.slo().expect("observatory attached");
    let mut submitted = 0u64;
    for class in Class::ALL {
        let s = slo.status(class);
        assert_eq!(
            s.submitted,
            s.completed + s.shed,
            "{} fleet books leak: {s:?}",
            class.name()
        );
        submitted += s.submitted;
    }
    // the class ledgers sum to the fleet identity, which the fleet
    // already enforces against its own counters
    assert_eq!(submitted, n as u64);
    assert_eq!(
        metrics.counter("fleet_submitted").get(),
        n as u64,
        "fleet counter disagrees with the class ledgers"
    );
    // deferrals happened (the staged cascade routes between tiers) and
    // were booked per class, one record per hop
    let total_deferred: u64 =
        Class::ALL.iter().map(|c| slo.status(*c).deferred).sum();
    let tier_deferred: u64 =
        (0..fleet.n_tiers()).map(|i| fleet.tier(i).deferred()).sum();
    assert!(total_deferred > 0, "the staged cascade never deferred");
    assert_eq!(
        total_deferred, tier_deferred,
        "per-class deferral books disagree with the tier counters"
    );
}
