//! Integration: load real AOT artifacts, execute via PJRT, and check
//! numerics against the manifest's recorded accuracies.
//!
//! Requires `make artifacts` to have run (skips otherwise).

use std::sync::Arc;

use abc_serve::runtime::engine::Engine;
use abc_serve::util::stats::binomial_se;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn manifest() -> Option<Manifest> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(root).expect("manifest loads"))
}

#[test]
fn tier_accuracy_matches_manifest() {
    let Some(m) = manifest() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    // Smallest suite keeps this test fast.
    let rt = SuiteRuntime::load(Arc::clone(&engine), &m, "synth-sst2", false).unwrap();
    let test = rt.dataset(&m, "test").unwrap();
    for tier_exe in &rt.tiers {
        let outs = tier_exe.run(&test.x, test.n).unwrap();
        assert_eq!(outs.len(), test.n);
        let hits = outs
            .iter()
            .zip(&test.y)
            .filter(|(o, &y)| o.majority == y)
            .count();
        let acc = hits as f64 / test.n as f64;
        let entry = rt.suite.tier(tier_exe.tier).unwrap();
        let want = entry.test_acc_ensemble;
        // The PJRT path must agree with the python eval up to vote-tie
        // handling noise; allow 4 standard errors + 1% slack.
        let tol = 4.0 * binomial_se(want, test.n) + 0.01;
        assert!(
            (acc - want).abs() <= tol,
            "tier {}: PJRT acc {acc:.4} vs manifest {want:.4} (tol {tol:.4})",
            tier_exe.tier
        );
    }
}

#[test]
fn outputs_are_well_formed() {
    let Some(m) = manifest() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let rt = SuiteRuntime::load(engine, &m, "synth-sst2", true).unwrap();
    let test = rt.dataset(&m, "test").unwrap();
    let n = 37; // deliberately not a bucket size
    let tier = &rt.tiers[0];
    let outs = tier.run(&test.x[..n * test.dim], n).unwrap();
    assert_eq!(outs.len(), n);
    for o in &outs {
        assert!((o.majority as usize) < rt.suite.classes);
        assert!((0.0..=1.0 + 1e-6).contains(&(o.vote_frac as f64)));
        assert!((0.0..=1.0 + 1e-6).contains(&(o.mean_score as f64)));
        // vote fraction is a multiple of 1/k
        let f = o.vote_frac * tier.k as f32;
        assert!((f - f.round()).abs() < 1e-4, "vote_frac {}", o.vote_frac);
    }
    // single-model artifact
    let single = rt.single(1).unwrap();
    let souts = single.run_single(&test.x[..n * test.dim], n).unwrap();
    assert_eq!(souts.len(), n);
    for s in &souts {
        assert!((s.pred as usize) < rt.suite.classes);
        assert!(s.confidence >= 1.0 / rt.suite.classes as f32 - 1e-4);
        assert!(s.confidence <= 1.0 + 1e-6);
    }
}

#[test]
fn batch_chunking_consistent_with_single_calls() {
    let Some(m) = manifest() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let rt = SuiteRuntime::load(engine, &m, "synth-sst2", false).unwrap();
    let test = rt.dataset(&m, "test").unwrap();
    let tier = &rt.tiers[0];
    // 300 rows forces chunking at max bucket 128
    let n = 300;
    let big = tier.run(&test.x[..n * test.dim], n).unwrap();
    // run each row individually (bucket 1) and compare predictions
    for i in (0..n).step_by(37) {
        let one = tier.run(test.row(i), 1).unwrap();
        assert_eq!(one[0].majority, big[i].majority, "row {i}");
        assert!((one[0].vote_frac - big[i].vote_frac).abs() < 1e-5);
        assert!((one[0].mean_score - big[i].mean_score).abs() < 1e-4);
    }
}

#[test]
fn logits_shape_and_argmax_consistency() {
    let Some(m) = manifest() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let rt = SuiteRuntime::load(engine, &m, "synth-sst2", false).unwrap();
    let test = rt.dataset(&m, "test").unwrap();
    let tier = &rt.tiers[1];
    let n = 20;
    let (outs, logits) = tier.run_with_logits(&test.x[..n * test.dim], n).unwrap();
    let c = rt.suite.classes;
    assert_eq!(logits.len(), tier.k * n * c);
    // majority label must win the member-argmax plurality vote
    for i in 0..n {
        let mut counts = vec![0usize; c];
        for mem in 0..tier.k {
            let off = (mem * n + i) * c;
            let row = &logits[off..off + c];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            counts[argmax] += 1;
        }
        let best = counts.iter().enumerate().max_by_key(|&(i2, &v)| (v, c - i2)).unwrap().0;
        assert_eq!(best as u32, outs[i].majority, "sample {i}");
    }
}

#[test]
fn parallel_execution_is_safe() {
    let Some(m) = manifest() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let rt = SuiteRuntime::load(engine, &m, "synth-sst2", false).unwrap();
    let test = Arc::new(rt.dataset(&m, "test").unwrap());
    let tier = Arc::clone(&rt.tiers[0]);
    let baseline = tier.run(&test.x[..8 * test.dim], 8).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let tier = Arc::clone(&tier);
            let test = Arc::clone(&test);
            std::thread::spawn(move || tier.run(&test.x[..8 * test.dim], 8).unwrap())
        })
        .collect();
    for h in handles {
        let got = h.join().unwrap();
        for (a, b) in got.iter().zip(&baseline) {
            assert_eq!(a.majority, b.majority);
            assert!((a.mean_score - b.mean_score).abs() < 1e-5);
        }
    }
}
