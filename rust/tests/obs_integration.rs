//! Integration: the observability layer (ISSUE 6) riding the real
//! serving stack -- no PJRT artifacts needed (synthetic backends).
//!
//! Covers the contracts the subsystem exists for:
//! * a traced request leaves a complete span lifecycle (enqueue,
//!   queue-wait, batch-assembly, infer, complete) with trace assembly
//!   happening at READ time, not on the hot path;
//! * 1-in-N sampling is deterministic by request id: `--trace-sample 1`
//!   captures every request, `--trace-sample N` exactly the ids
//!   divisible by N;
//! * a fleet's per-tier queue-wait/service-time histograms are ALIASES
//!   of the tier pools' histograms (same atomics) and the router's
//!   defer spans agree with each request's exit tier;
//! * hot-path counters (striped across shards) fold to exact totals
//!   under concurrent submitters.

use std::sync::Arc;
use std::time::Duration;

use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::StageClassifier;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::coordinator::router::{TierSpec, TieredFleet, TieredFleetConfig};
use abc_serve::cost::rental::Gpu;
use abc_serve::metrics::Metrics;
use abc_serve::obs::{ObsHook, SpanKind, SpanRecord, Tracer};
use abc_serve::trafficgen::{StagedSynthetic, SyntheticClassifier, Trace};
use abc_serve::types::Request;

use abc_serve::data::workload::Arrival;

const DIM: usize = 4;
const LEVELS: usize = 3;
const MAX_QUEUE: usize = 64;

/// Fast synthetic cascade: these tests are about spans and counters,
/// not capacity, so service time is microseconds.
fn classifier() -> Arc<SyntheticClassifier> {
    Arc::new(SyntheticClassifier::new(
        DIM,
        LEVELS,
        Duration::ZERO,
        Duration::from_micros(50),
    ))
}

fn pool_cfg(replicas: usize) -> PoolConfig {
    PoolConfig {
        replicas,
        max_queue: MAX_QUEUE,
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        },
        ..PoolConfig::default()
    }
}

fn traced_pool(sample_every: u64, replicas: usize) -> (Arc<ReplicaPool>, Arc<Tracer>) {
    let tracer = Tracer::new(sample_every);
    let pool = Arc::new(ReplicaPool::spawn_with_obs(
        classifier(),
        pool_cfg(replicas),
        Metrics::new(),
        None,
        ObsHook::monolithic(Some(Arc::clone(&tracer))),
    ));
    (pool, tracer)
}

fn req(id: u64) -> Request {
    Request {
        id,
        features: vec![id as f32 * 0.61 - 7.0, 0.0, 0.0, 0.0],
        arrival_s: 0.0,
        class: abc_serve::types::Class::Standard,
    }
}

fn spans_of(spans: &[SpanRecord], id: u64) -> Vec<SpanKind> {
    spans.iter().filter(|s| s.request_id == id).map(|s| s.kind).collect()
}

#[test]
fn sample_one_traces_every_request_with_a_full_lifecycle() {
    let (pool, tracer) = traced_pool(1, 1);
    let n = 40u64;
    for id in 0..n {
        pool.infer(req(id)).unwrap();
    }
    let spans = tracer.snapshot();
    assert_eq!(tracer.dropped(), 0);
    for id in 0..n {
        let kinds = spans_of(&spans, id);
        for want in [
            SpanKind::Enqueue,
            SpanKind::QueueWait,
            SpanKind::Infer,
            SpanKind::Complete,
        ] {
            assert!(
                kinds.contains(&want),
                "request {id} is missing a {want:?} span: {kinds:?}"
            );
        }
        assert!(!kinds.contains(&SpanKind::Shed), "nothing was shed");
    }
    // monolithic pool: every span carries tier 0
    assert!(spans.iter().all(|s| s.tier == 0));
    // batch assembly is attributed once per batch, to one member
    let assemblies = spans.iter().filter(|s| s.kind == SpanKind::BatchAssembly).count();
    let batches = pool.metrics().counter("batches_ok").get() as usize;
    assert_eq!(assemblies, batches);
    // read-time grouping: one trace per request, spans time-ordered
    let traces = tracer.snapshot_traces();
    let arr = traces.as_arr().expect("traces is an array");
    assert_eq!(arr.len(), n as usize);
    for t in arr {
        let spans = t.get("spans").as_arr().unwrap();
        assert!(!spans.is_empty());
        let ts: Vec<f64> =
            spans.iter().map(|s| s.get("ts_s").as_f64().unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "spans out of order: {ts:?}");
    }
}

#[test]
fn sample_n_traces_exactly_the_ids_divisible_by_n() {
    let (pool, tracer) = traced_pool(4, 1);
    let n = 40u64;
    for id in 0..n {
        pool.infer(req(id)).unwrap();
    }
    let spans = tracer.snapshot();
    for id in 0..n {
        let traced = spans.iter().any(|s| s.request_id == id);
        assert_eq!(
            traced,
            id % 4 == 0,
            "id {id}: sampling must be deterministic (id % 4 == 0)"
        );
    }
    // every sampled request still gets its full lifecycle
    for id in (0..n).step_by(4) {
        let kinds = spans_of(&spans, id);
        assert!(kinds.contains(&SpanKind::Enqueue));
        assert!(kinds.contains(&SpanKind::Complete));
    }
}

#[test]
fn shed_requests_get_a_shed_span_not_a_complete() {
    // zero replicas is invalid, so saturate a tiny pool instead: one
    // replica, queue of 1, slow rows, and a flood of concurrent submits
    let tracer = Tracer::new(1);
    let pool = Arc::new(ReplicaPool::spawn_with_obs(
        Arc::new(SyntheticClassifier::new(
            DIM,
            LEVELS,
            Duration::ZERO,
            Duration::from_millis(5),
        )),
        PoolConfig {
            replicas: 1,
            max_queue: 1,
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
        None,
        ObsHook::monolithic(Some(Arc::clone(&tracer))),
    ));
    let mut pending = Vec::new();
    let mut shed_ids = Vec::new();
    for id in 0..64 {
        match pool.submit(req(id)) {
            Ok(rx) => pending.push(rx),
            Err(_) => shed_ids.push(id),
        }
    }
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    assert!(!shed_ids.is_empty(), "the flood must overflow a queue of 1");
    let spans = tracer.snapshot();
    for id in shed_ids {
        let kinds = spans_of(&spans, id);
        assert!(kinds.contains(&SpanKind::Shed), "shed id {id}: {kinds:?}");
        assert!(!kinds.contains(&SpanKind::Complete));
        assert!(!kinds.contains(&SpanKind::Enqueue));
    }
}

#[test]
fn queue_wait_and_service_histograms_fill_without_tracing() {
    // the per-tier breakdown is a first-class metric: it must populate
    // even when no tracer is attached
    let pool = Arc::new(ReplicaPool::spawn(classifier(), pool_cfg(1), Metrics::new()));
    for id in 0..20 {
        pool.infer(req(id)).unwrap();
    }
    let m = pool.metrics();
    assert_eq!(m.histogram("queue_wait_s").count(), 20);
    assert_eq!(m.histogram("service_s").count(), 20);
    assert!(m.histogram("service_s").mean() > 0.0);
}

#[test]
fn fleet_aliases_tier_histograms_and_defers_match_exit_tiers() {
    let tracer = Tracer::new(1);
    let staged = Arc::new(StagedSynthetic::new(
        SyntheticClassifier::new(DIM, LEVELS, Duration::ZERO, Duration::from_micros(50)),
        vec![0.15, 0.25, 0.60],
    ));
    let metrics = Metrics::new();
    let fleet = Arc::new(
        TieredFleet::spawn_with_obs(
            staged as Arc<dyn StageClassifier>,
            TieredFleetConfig {
                tiers: vec![
                    TierSpec::fixed(Gpu::V100, 1, MAX_QUEUE),
                    TierSpec::fixed(Gpu::A6000, 1, MAX_QUEUE),
                    TierSpec::fixed(Gpu::H100, 1, MAX_QUEUE),
                ],
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: Duration::from_millis(1),
                },
                class_weights: None,
            },
            Arc::clone(&metrics),
            Some(Arc::clone(&tracer)),
        )
        .unwrap(),
    );
    let n = 48u64;
    for id in 0..n {
        fleet.infer(req(id)).unwrap();
    }
    let spans = tracer.snapshot();

    // every request completes; its defer-hop count equals the tier its
    // complete span carries (tier 0 exit -> 0 defers, tier 2 -> 2)
    for id in 0..n {
        let mine: Vec<&SpanRecord> =
            spans.iter().filter(|s| s.request_id == id).collect();
        let complete: Vec<&&SpanRecord> =
            mine.iter().filter(|s| s.kind == SpanKind::Complete).collect();
        assert_eq!(complete.len(), 1, "id {id} must complete exactly once");
        let defers = mine.iter().filter(|s| s.kind == SpanKind::Defer).count();
        assert_eq!(defers, complete[0].tier, "id {id}: defer hops vs exit tier");
    }
    // the synthetic feature spread must actually exercise deferral, or
    // the assertions above are vacuous
    assert!(
        spans.iter().any(|s| s.kind == SpanKind::Defer),
        "no request deferred past tier 0 -- widen the feature spread"
    );

    // tier 0 served every request; its histograms are fleet-visible
    // under the aliased names AND pool-visible under the plain names,
    // with identical counts (same atomics, not copies)
    let t0_wait = metrics.histogram("tier_0_queue_wait_s");
    assert_eq!(t0_wait.count(), n);
    let pool_wait = fleet.tiers()[0].pool().metrics().histogram("queue_wait_s");
    assert_eq!(pool_wait.count(), t0_wait.count());
    assert_eq!(metrics.histogram("tier_0_service_s").count(), n);
    // deeper tiers saw exactly the deferred share
    let deferred_past_0 =
        spans.iter().filter(|s| s.kind == SpanKind::Defer && s.tier == 0).count() as u64;
    assert_eq!(metrics.histogram("tier_1_queue_wait_s").count(), deferred_past_0);
}

#[test]
fn counters_fold_exactly_under_concurrent_submitters() {
    let pool = Arc::new(ReplicaPool::spawn(classifier(), pool_cfg(2), Metrics::new()));
    let threads = 8u64;
    let per_thread = 50u64;
    std::thread::scope(|s| {
        for t in 0..threads {
            let pool = Arc::clone(&pool);
            s.spawn(move || {
                for i in 0..per_thread {
                    pool.infer(req(t * per_thread + i)).unwrap();
                }
            });
        }
    });
    let total = threads * per_thread;
    // requests_submitted is the striped counter: the fold across
    // shards must be exact, not approximate
    assert_eq!(pool.metrics().counter("requests_submitted").get(), total);
    assert_eq!(pool.metrics().histogram("request_latency_s").count(), total);
}

#[test]
fn loadgen_against_a_traced_pool_stays_consistent() {
    // spans under real concurrency: every sampled id has exactly one
    // terminal span (complete XOR shed), never both, never zero
    let (pool, tracer) = traced_pool(1, 2);
    let n = 400;
    let trace = Arc::new(Trace::synth(
        Arrival::Poisson { rate: 4000.0 },
        n,
        DIM,
        17,
    ));
    let report = abc_serve::trafficgen::LoadGen { workers: 64, class_mix: None }
        .run(&pool, trace, &Metrics::new())
        .unwrap();
    assert_eq!(report.completed + report.shed + report.errors, n as u64);
    let spans = tracer.snapshot();
    let mut completes = 0u64;
    let mut sheds = 0u64;
    for id in 0..n as u64 {
        let kinds = spans_of(&spans, id);
        let c = kinds.iter().filter(|k| **k == SpanKind::Complete).count();
        let s = kinds.iter().filter(|k| **k == SpanKind::Shed).count();
        assert_eq!(c + s, 1, "id {id}: exactly one terminal span, got {kinds:?}");
        completes += c as u64;
        sheds += s as u64;
    }
    assert_eq!(completes, report.completed);
    assert_eq!(sheds, report.shed);
}
