//! Integration: open-loop load generation against the replica pool and
//! the TCP front end -- no PJRT artifacts needed (synthetic backend).
//!
//! Covers the serving-economics claims the subsystem exists to measure:
//! * more replicas sustain more offered load before the latency knee;
//! * under saturation the pool sheds (`Overloaded`) with a hard bound on
//!   outstanding work instead of growing queues without bound;
//! * traces round-trip through the ABDS container;
//! * the TCP server survives a load run and shuts down cleanly.
//!
//! Timing margins are deliberately loose: the synthetic classifier's
//! `sleep`-based service time is a *lower* bound on real elapsed time,
//! so a slow CI machine only lowers capacity -- every assertion below
//! stays valid in that direction.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::server::{serve, Client};
use abc_serve::trafficgen::{LoadGen, SyntheticClassifier, TcpTarget, Trace};

const DIM: usize = 4;

/// The saturation tests reason about wall-clock capacity; run them one
/// at a time so they don't contend for cores with each other.
static TIMING_LOCK: Mutex<()> = Mutex::new(());

fn timing_guard() -> std::sync::MutexGuard<'static, ()> {
    TIMING_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// 2ms per row, no fixed cost, batches of 8: one replica sustains
/// ~500 rows/s regardless of how slow the host is (sleep only overshoots).
fn classifier() -> Arc<SyntheticClassifier> {
    Arc::new(SyntheticClassifier::new(
        DIM,
        3,
        Duration::ZERO,
        Duration::from_millis(2),
    ))
}

fn pool(replicas: usize, max_queue: usize) -> Arc<ReplicaPool> {
    Arc::new(ReplicaPool::spawn(
        classifier(),
        PoolConfig {
            replicas,
            max_queue,
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
    ))
}

#[test]
fn four_replicas_sustain_more_offered_load_than_one() {
    let _serial = timing_guard();
    // offered 800 rps for 0.5s: ~1.6x one replica's ~500 rows/s capacity,
    // ~0.4x a 4-replica pool's.
    let trace = Arc::new(Trace::synth(Arrival::Uniform { rate: 800.0 }, 400, DIM, 11));
    let gen = LoadGen { workers: 80, class_mix: None };

    let pool1 = pool(1, 16);
    let r1 = gen.run(&pool1, Arc::clone(&trace), &Metrics::new()).unwrap();
    let pool4 = pool(4, 16);
    let r4 = gen.run(&pool4, Arc::clone(&trace), &Metrics::new()).unwrap();

    // the single replica is past saturation: it must shed
    assert!(r1.shed > 0, "1 replica at 1.6x capacity never shed: {r1:?}");
    assert_eq!(r1.errors, 0, "{r1:?}");
    assert_eq!(r4.errors, 0, "{r4:?}");
    // headline: measurably higher goodput with 4 replicas.  This is the
    // slow-CI-robust comparison: if sleeps overshoot so much that even
    // the 4-replica pool saturates, both runs are capacity-bound and the
    // ~4x capacity gap keeps the ratio comfortably above 1.2.
    assert!(
        r4.completed as f64 >= r1.completed as f64 * 1.2,
        "4-replica goodput not higher: {} vs {}",
        r4.completed,
        r1.completed
    );
    assert!(r4.shed < r1.shed, "shedding should drop with replicas");
    // soft absolute floor: 4 replicas at nominal 0.4x utilisation should
    // complete nearly everything; 200 tolerates ~5x sleep overshoot
    assert!(
        r4.completed >= 200,
        "4 replicas at 0.4x capacity dropped most work: {r4:?}"
    );
    // everything drained
    assert_eq!(pool1.total_outstanding(), 0);
    assert_eq!(pool4.total_outstanding(), 0);
    assert_eq!(r1.completed + r1.shed, 400);
    assert_eq!(r4.completed + r4.shed, 400);
}

#[test]
fn saturation_sheds_with_bounded_outstanding() {
    let _serial = timing_guard();
    // offered ~1000 rps against one ~500 rows/s replica: 2x saturation
    let p = pool(1, 8);
    let trace = Arc::new(Trace::synth(Arrival::Poisson { rate: 1000.0 }, 300, DIM, 3));

    // sample the outstanding count throughout the run
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let p = Arc::clone(&p);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut max_seen = 0usize;
            while !stop.load(Ordering::SeqCst) {
                max_seen = max_seen.max(p.total_outstanding());
                std::thread::sleep(Duration::from_millis(1));
            }
            max_seen
        })
    };

    let metrics = Metrics::new();
    let report = LoadGen { workers: 64, class_mix: None }
        .run(&p, Arc::clone(&trace), &metrics)
        .unwrap();
    stop.store(true, Ordering::SeqCst);
    let max_outstanding = sampler.join().unwrap();

    // sheds instead of queueing: the bounded queue never exceeds its cap
    assert!(report.shed > 0, "2x saturation never shed: {report:?}");
    assert!(report.completed > 0, "{report:?}");
    assert_eq!(report.errors, 0, "{report:?}");
    assert_eq!(report.completed + report.shed, 300);
    assert!(
        max_outstanding <= 8,
        "outstanding grew past max_queue: {max_outstanding}"
    );
    assert_eq!(p.total_outstanding(), 0, "drained after the run");
    assert_eq!(
        p.metrics().counter("requests_shed").get(),
        report.shed,
        "pool and loadgen disagree on sheds"
    );
}

#[test]
fn trace_roundtrips_through_abds_file() {
    let dir = std::env::temp_dir().join(format!("abc-trace-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.abds");

    let t = Trace::synth(
        Arrival::OnOff { rate: 400.0, on_s: 0.05, off_s: 0.2 },
        120,
        6,
        21,
    );
    t.save(&path).unwrap();
    let back = Trace::load(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    assert_eq!(back.len(), 120);
    assert_eq!(back.dim, 6);
    assert_eq!(back.features, t.features);
    assert!(back.arrivals.windows(2).all(|w| w[0] <= w[1]), "sorted");
    for (a, b) in back.arrivals.iter().zip(&t.arrivals) {
        assert!((a - b).abs() < 1e-3, "f32 arrival precision: {a} vs {b}");
    }
}

#[test]
fn tcp_server_handles_load_run_and_shuts_down() {
    let _serial = timing_guard();
    let port = 7993;
    let p = pool(2, 32);
    let metrics_handle = Arc::clone(p.metrics());
    let server = std::thread::spawn(move || serve(p, port));
    std::thread::sleep(Duration::from_millis(300));

    // light load through real sockets: everything should complete
    let trace = Arc::new(Trace::synth(Arrival::Poisson { rate: 200.0 }, 150, DIM, 5));
    let report = LoadGen { workers: 8, class_mix: None }
        .run(&TcpTarget { port }, trace, &Metrics::new())
        .unwrap();
    assert_eq!(report.errors, 0, "{report:?}");
    assert!(
        report.completed >= 140,
        "TCP load run dropped work: {report:?}"
    );
    assert!(metrics_handle.counter("requests_submitted").get() >= 140);

    // the shutdown-hang fix: serve() must join all handler threads even
    // though the loadgen's worker connections are idle-open
    let mut client = Client::connect(port).unwrap();
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}
