//! Integration: serving pipeline + TCP front end over real artifacts.
//!
//! (The artifact-backed tests skip when `artifacts/manifest.json` is
//! absent; the `stats`/gear wire tests at the bottom run anywhere on
//! the synthetic backend, like `loadgen_integration.rs`.)

use std::sync::Arc;
use std::time::Duration;

use abc_serve::calib;
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::Cascade;
use abc_serve::coordinator::pipeline::Pipeline;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::metrics::Metrics;
use abc_serve::obs::{ObsHook, Tracer};
use abc_serve::planner::{GearHandle, GearPlan};
use abc_serve::server::{serve, serve_with, Client, Frontend};
use abc_serve::trafficgen::SyntheticClassifier;
use abc_serve::types::{Class, Request, RuleKind};
use abc_serve::util::json::Json;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn boot(suite: &str) -> Option<(Arc<Cascade>, Arc<SuiteRuntime>, Manifest)> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let manifest = Manifest::load(root).unwrap();
    let engine = Arc::new(abc_serve::runtime::engine::Engine::cpu().unwrap());
    let rt = Arc::new(SuiteRuntime::load(engine, &manifest, suite, false).unwrap());
    let val = rt.dataset(&manifest, "val").unwrap();
    let cal = calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 100, 0.05).unwrap();
    let cascade = Arc::new(Cascade::new(rt.tiers.clone(), cal.policy.clone()));
    Some((cascade, rt, manifest))
}

fn batcher_cfg() -> BatcherConfig {
    BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(1) }
}

#[test]
fn pipeline_single_and_concurrent_requests() {
    let Some((cascade, rt, manifest)) = boot("synth-sst2") else { return };
    let pipeline = Arc::new(Pipeline::spawn(cascade, batcher_cfg(), Metrics::new()));
    let test = rt.dataset(&manifest, "test").unwrap();

    // single blocking request
    let v = pipeline
        .infer(Request {
            id: 1,
            features: test.row(0).to_vec(),
            arrival_s: 0.0,
            class: Class::Standard,
        })
        .unwrap();
    assert_eq!(v.request_id, 1);
    assert!((v.prediction as usize) < rt.suite.classes);
    assert!(v.exit_tier >= 1 && v.exit_tier <= rt.n_tiers());
    assert!(!v.tier_scores.is_empty());

    // concurrent submits batch together and all complete
    let rxs: Vec<_> = (0..50)
        .map(|i| {
            pipeline
                .submit(Request {
                    id: 100 + i,
                    features: test.row(i as usize).to_vec(),
                    arrival_s: 0.0,
                    class: Class::Standard,
                })
                .unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let v = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("verdict arrives")
            .expect("no error");
        assert_eq!(v.request_id, 100 + i as u64);
    }
    // metrics recorded; all outstanding slots released
    assert!(pipeline.metrics().counter("requests_submitted").get() >= 51);
    assert!(pipeline.metrics().histogram("request_latency_s").count() >= 51);
    assert_eq!(pipeline.outstanding(), 0);
}

#[test]
fn pipeline_rejects_bad_dim() {
    let Some((cascade, _, _)) = boot("synth-sst2") else { return };
    let pipeline = Arc::new(Pipeline::spawn(cascade, batcher_cfg(), Metrics::new()));
    let err = pipeline
        .submit(Request {
            id: 9,
            features: vec![0.0; 3],
            arrival_s: 0.0,
            class: Class::Standard,
        })
        .unwrap_err();
    assert!(err.to_string().contains("features"));
}

#[test]
fn tcp_server_roundtrip() {
    let Some((cascade, rt, manifest)) = boot("synth-sst2") else { return };
    let pool = Arc::new(ReplicaPool::spawn(
        cascade,
        PoolConfig {
            replicas: 2,
            max_queue: 64,
            batcher: batcher_cfg(),
            ..PoolConfig::default()
        },
        Metrics::new(),
    ));
    let test = rt.dataset(&manifest, "test").unwrap();
    let port = 7991;
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    // valid inference
    let (pred, exit_tier) = client.infer(5, test.row(3)).unwrap();
    assert!((pred as usize) < rt.suite.classes);
    assert!(exit_tier >= 1);
    // metrics command
    let reply = client.roundtrip(r#"{"cmd":"metrics"}"#).unwrap();
    assert!(reply.contains("metrics"));
    // malformed line gets an error, connection stays usable
    let reply = client.roundtrip("garbage").unwrap();
    assert!(reply.contains("error"));
    let (_, _) = client.infer(6, test.row(4)).unwrap();
    // wrong-dim features produce a server-side error reply
    let reply = client
        .roundtrip(r#"{"id": 7, "features": [1.0, 2.0]}"#)
        .unwrap();
    assert!(reply.contains("error"), "got {reply}");
    // shutdown joins cleanly (handler read timeouts release the threads)
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

// ----- artifact-free wire tests (synthetic backend) --------------------

fn synthetic_pool(gear: Option<Arc<GearHandle>>) -> Arc<ReplicaPool> {
    let classifier = Arc::new(SyntheticClassifier::new(
        4,
        3,
        Duration::ZERO,
        Duration::from_micros(100),
    ));
    let cfg = PoolConfig {
        replicas: 1,
        max_queue: 64,
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
        ..PoolConfig::default()
    };
    Arc::new(match gear {
        Some(h) => ReplicaPool::spawn_geared(classifier, cfg, Metrics::new(), h),
        None => ReplicaPool::spawn(classifier, cfg, Metrics::new()),
    })
}

#[test]
fn stats_command_roundtrips_structured_snapshot() {
    let port = 7992;
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    // before any inference the reply is already well-formed
    let empty = client.stats().unwrap();
    assert!(empty.get("stats").get("counters").as_obj().is_some());

    for id in 0..3 {
        client.infer(id, &[0.5, -0.5, 0.25, 1.0]).unwrap();
    }
    let v = client.stats().unwrap();
    let stats = v.get("stats");
    assert!(
        stats.get("counters").get("requests_submitted").as_u64().unwrap() >= 3,
        "stats: {v}"
    );
    let lat = stats.get("histograms").get("request_latency_s");
    assert!(lat.get("n").as_u64().unwrap() >= 3, "stats: {v}");
    assert!(lat.get("p99").as_f64().unwrap() > 0.0);
    // ungeared pool: no gear field on verdicts
    let reply = client
        .roundtrip(r#"{"id": 9, "features": [0.1, 0.2, 0.3, 0.4]}"#)
        .unwrap();
    let parsed = Json::parse(&reply).unwrap();
    assert!(parsed.get("gear").as_u64().is_none(), "got {reply}");

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn events_command_roundtrips_the_controller_log() {
    let port = 7995;
    let pool = synthetic_pool(None);
    // seed the shared registry's event log the way the control loop
    // would
    pool.metrics().events().record(abc_serve::metrics::EventRecord {
        kind: abc_serve::metrics::EventKind::Shift,
        decider: "gear",
        trigger: "rate",
        tier: 0,
        old_gear: 0,
        new_gear: 1,
        old_replicas: 2,
        new_replicas: 2,
        class: None,
    });
    pool.metrics().events().record(abc_serve::metrics::EventRecord {
        kind: abc_serve::metrics::EventKind::Scale,
        decider: "scale",
        trigger: "pressure",
        tier: 0,
        old_gear: 1,
        new_gear: 1,
        old_replicas: 2,
        new_replicas: 4,
        class: None,
    });
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    let reply = client.events().unwrap();
    let events = reply.get("events").as_arr().unwrap();
    assert_eq!(events.len(), 2, "got {reply}");
    assert_eq!(events[0].get("kind").as_str(), Some("shift"));
    assert_eq!(events[0].get("trigger").as_str(), Some("rate"));
    assert_eq!(events[0].get("decider").as_str(), Some("gear"));
    assert_eq!(events[0].get("tier").as_u64(), Some(0));
    assert_eq!(events[1].get("kind").as_str(), Some("scale"));
    assert_eq!(events[1].get("decider").as_str(), Some("scale"));
    assert_eq!(events[1].get("old_replicas").as_u64(), Some(2));
    assert_eq!(events[1].get("new_replicas").as_u64(), Some(4));
    assert!(events[0].get("ts_s").as_f64().unwrap() > 0.0);
    assert_eq!(reply.get("dropped").as_u64(), Some(0));

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn prom_command_serves_the_text_exposition() {
    let port = 7996;
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    for id in 0..5 {
        client.infer(id, &[0.5, -0.5, 0.25, 1.0]).unwrap();
    }
    let text = client.prom().unwrap();
    assert!(
        text.contains("# TYPE requests_submitted counter"),
        "exposition:\n{text}"
    );
    assert!(text.contains("requests_submitted 5"), "exposition:\n{text}");
    assert!(
        text.contains("# TYPE request_latency_s summary"),
        "exposition:\n{text}"
    );
    assert!(text.contains("request_latency_s_count 5"), "exposition:\n{text}");
    assert!(
        text.contains(r#"request_latency_s{quantile="0.99"}"#),
        "exposition:\n{text}"
    );
    // every line is scrape-parseable: a comment or `name value`
    for line in text.lines() {
        assert!(
            line.starts_with("# ") || line.split_whitespace().count() == 2,
            "bad exposition line: {line:?}"
        );
    }

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn traces_command_roundtrips_sampled_spans() {
    let port = 7997;
    let tracer = Tracer::new(2);
    let classifier = Arc::new(SyntheticClassifier::new(
        4,
        3,
        Duration::ZERO,
        Duration::from_micros(100),
    ));
    let pool = Arc::new(ReplicaPool::spawn_with_obs(
        classifier,
        PoolConfig {
            replicas: 1,
            max_queue: 64,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            ..PoolConfig::default()
        },
        Metrics::new(),
        None,
        ObsHook::monolithic(Some(tracer)),
    ));
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    for id in 0..10 {
        client.infer(id, &[0.5, -0.5, 0.25, 1.0]).unwrap();
    }
    let reply = client.traces().unwrap();
    assert_eq!(reply.get("sample_every").as_u64(), Some(2), "got {reply}");
    assert_eq!(reply.get("dropped").as_u64(), Some(0));
    let traces = reply.get("traces").as_arr().unwrap();
    // ids 0,2,4,6,8 sampled
    assert_eq!(traces.len(), 5, "got {reply}");
    for t in traces {
        assert_eq!(t.get("request_id").as_u64().unwrap() % 2, 0);
        let spans = t.get("spans").as_arr().unwrap();
        assert!(
            spans.iter().any(|s| s.get("kind").as_str() == Some("complete")),
            "trace lacks a complete span: {t}"
        );
    }

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn traces_command_on_an_untraced_server_is_well_formed() {
    let port = 7998;
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    let reply = client.traces().unwrap();
    assert_eq!(reply.get("sample_every").as_u64(), Some(0), "got {reply}");
    assert_eq!(reply.get("traces").as_arr().map(<[Json]>::len), Some(0));

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn slo_command_on_a_classless_server_is_well_formed() {
    let port = 7999;
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    let reply = client.slo().unwrap();
    // no observatory: same shape, empty class list, zero goal
    assert_eq!(
        reply.get("slo").get("classes").as_arr().map(<[Json]>::len),
        Some(0),
        "got {reply}"
    );
    assert_eq!(reply.get("slo").get("goal").as_f64(), Some(0.0), "got {reply}");

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn slo_command_roundtrips_per_class_books() {
    use abc_serve::obs::slo::{SloConfig, SloObservatory};
    let port = 8000;
    let classifier = Arc::new(SyntheticClassifier::new(
        4,
        3,
        Duration::ZERO,
        Duration::from_micros(100),
    ));
    let metrics = Metrics::new();
    let pool = Arc::new(ReplicaPool::spawn(
        classifier,
        PoolConfig {
            replicas: 1,
            max_queue: 64,
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
            ..PoolConfig::default()
        },
        Arc::clone(&metrics),
    ));
    pool.attach_slo(SloObservatory::new(SloConfig::default(), &metrics));
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    let feats = [0.5, -0.5, 0.25, 1.0];
    client.infer_reply_class(1, &feats, Some(Class::Premium)).unwrap();
    client.infer_reply_class(2, &feats, Some(Class::Batch)).unwrap();
    // an untagged line lands in the standard class
    client.infer(3, &feats).unwrap();

    let reply = client.slo().unwrap();
    let slo = reply.get("slo");
    let classes = slo.get("classes").as_arr().unwrap();
    assert_eq!(classes.len(), 3, "got {reply}");
    for (entry, (name, target)) in classes
        .iter()
        .zip([("premium", 0.05), ("standard", 0.25), ("batch", 2.0)])
    {
        assert_eq!(entry.get("class").as_str(), Some(name), "got {reply}");
        assert!(
            (entry.get("target_s").as_f64().unwrap() - target).abs() < 1e-9,
            "got {reply}"
        );
        assert_eq!(entry.get("submitted").as_u64(), Some(1), "got {reply}");
        assert_eq!(entry.get("completed").as_u64(), Some(1), "got {reply}");
        assert_eq!(entry.get("shed").as_u64(), Some(0), "got {reply}");
    }
    assert!((slo.get("goal").as_f64().unwrap() - 0.95).abs() < 1e-9, "got {reply}");
    // the per-class counters also surface in the scrape exposition
    let text = client.prom().unwrap();
    assert!(text.contains("class_premium_submitted 1"), "exposition:\n{text}");
    assert!(text.contains("class_batch_completed 1"), "exposition:\n{text}");

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

// ----- frontend tests: reactor vs threads ------------------------------

/// Shutdown drain pin (both frontends): a single write carrying a
/// complete infer line AND the shutdown line.  Both lines are "already
/// received" when the server begins stopping, so the infer must still
/// be answered -- in order, before the ack -- and the connection must
/// close cleanly with the server joining promptly.
fn pipelined_shutdown_roundtrip(frontend: Frontend, port: u16) {
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || serve_with(pool, port, frontend));
    std::thread::sleep(Duration::from_millis(300));

    use std::io::{BufRead, BufReader, Read, Write};
    let mut stream = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .write_all(
            b"{\"id\":1,\"features\":[0.5,-0.5,0.25,1.0]}\n{\"cmd\":\"shutdown\"}\n",
        )
        .unwrap();
    let mut reader = BufReader::new(stream);
    let mut infer_reply = String::new();
    reader.read_line(&mut infer_reply).unwrap();
    assert!(
        infer_reply.contains("\"prediction\""),
        "{}: infer line not answered before close: {infer_reply:?}",
        frontend.name()
    );
    assert_eq!(
        Json::parse(infer_reply.trim()).unwrap().get("id").as_u64(),
        Some(1),
        "{}: {infer_reply:?}",
        frontend.name()
    );
    let mut ack = String::new();
    reader.read_line(&mut ack).unwrap();
    assert!(
        ack.contains("\"shutdown\":true"),
        "{}: expected the shutdown ack after the infer reply: {ack:?}",
        frontend.name()
    );
    // then a clean EOF: nothing else rides the connection
    let mut rest = String::new();
    let _ = reader.read_to_string(&mut rest);
    assert_eq!(rest.trim(), "", "{}: bytes after the ack", frontend.name());
    // and the server joins within the drain bound
    let t0 = std::time::Instant::now();
    server.join().unwrap().unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "{}: drain took {:?}",
        frontend.name(),
        t0.elapsed()
    );
}

#[test]
fn shutdown_drains_pipelined_lines_on_the_reactor_frontend() {
    pipelined_shutdown_roundtrip(Frontend::Reactor, 8010);
}

#[test]
fn shutdown_drains_pipelined_lines_on_the_threaded_frontend() {
    pipelined_shutdown_roundtrip(Frontend::Threads, 8011);
}

/// Blank the one nondeterministic reply field (`latency_s`) so wire
/// replies can be compared byte-for-byte across frontends and shard
/// counts.
fn normalize_latency(mut r: String) -> String {
    if let Some(i) = r.find("\"latency_s\":") {
        let j = r[i..].find(',').map(|o| i + o).unwrap_or(r.len());
        r.replace_range(i..j, "\"latency_s\":0");
    }
    r
}

/// Drive one frontend through a mixed request script and collect its
/// reply lines, with the one nondeterministic field (`latency_s`)
/// normalized away.
fn frontend_replies(frontend: Frontend, port: u16, lines: &[&str]) -> Vec<String> {
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || serve_with(pool, port, frontend));
    std::thread::sleep(Duration::from_millis(300));
    let mut client = Client::connect(port).unwrap();
    let mut out = Vec::new();
    for line in lines {
        out.push(normalize_latency(client.roundtrip(line).unwrap()));
    }
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
    out
}

#[test]
fn frontends_answer_byte_identically() {
    // deterministic replies: the synthetic classifier is a pure
    // function of the features, and every error string comes from the
    // shared dispatch path
    let lines = [
        r#"{"id":1,"features":[0.5,-0.5,0.25,1.0]}"#,
        r#"{"id":2,"features":[0.1,0.2,0.3,0.4],"class":"premium"}"#,
        r#"{"id":3,"features":[0.9,0.9,0.9,0.9],"class":null}"#,
        "garbage",
        r#"{"cmd":"nope"}"#,
        r#"{"id":4}"#,
        r#"{"id":5,"features":[]}"#,
        r#"{"id":6,"features":["x"]}"#,
        r#"{"id":7,"features":[1.0],"class":"gold"}"#,
        r#"{"id":8,"features":[1.0],"class":3}"#,
        r#"{"id":9.5,"features":[1.0]}"#,
    ];
    let threads = frontend_replies(Frontend::Threads, 8012, &lines);
    let reactor = frontend_replies(Frontend::Reactor, 8013, &lines);
    assert_eq!(threads, reactor, "wire replies must be byte-identical");
}

#[cfg(unix)]
#[test]
fn reactor_poll_fallback_serves_and_drains() {
    use abc_serve::server::reactor::{serve_reactor_with, ReactorConfig};
    let port = 8014;
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || {
        serve_reactor_with(
            pool,
            port,
            ReactorConfig { force_poll: true, ..ReactorConfig::default() },
        )
    });
    std::thread::sleep(Duration::from_millis(300));
    let mut client = Client::connect(port).unwrap();
    for id in 0..5 {
        client.infer(id, &[0.5, -0.5, 0.25, 1.0]).unwrap();
    }
    let stats = client.stats().unwrap();
    assert!(
        stats.get("stats").get("counters").get("requests_submitted").as_u64()
            >= Some(5),
        "got {stats}"
    );
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn reactor_multiplexes_many_connections_with_fifo_replies() {
    let port = 8015;
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || serve_with(pool, port, Frontend::Reactor));
    std::thread::sleep(Duration::from_millis(300));

    // many concurrent connections, one infer each -- all multiplexed
    // over the single reactor thread
    let mut joins = Vec::new();
    for c in 0..40u64 {
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(port).unwrap();
            client.infer(c, &[0.5, -0.5, 0.25, 1.0]).unwrap();
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    // one connection pipelines 32 lines in a single write; replies come
    // back in dispatch order even though workers finish out of order
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut batch = String::new();
    for id in 0..32 {
        batch.push_str(&format!(
            "{{\"id\":{id},\"features\":[0.5,-0.5,0.25,1.0]}}\n"
        ));
    }
    stream.write_all(batch.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    for id in 0..32 {
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let v = Json::parse(reply.trim()).unwrap();
        assert_eq!(v.get("id").as_u64(), Some(id), "reply out of order: {reply}");
    }
    drop(reader); // EOF: the reactor reaps the connection

    let mut client = Client::connect(port).unwrap();
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// Drive a sharded reactor with `conns` concurrent connections each
/// pipelining a deterministic mixed script of `lines` lines in one
/// write, and collect every connection's normalized reply lines in
/// arrival order.
#[cfg(unix)]
fn sharded_replies(shards: usize, port: u16, conns: usize, lines: usize) -> Vec<Vec<String>> {
    use abc_serve::server::reactor::{serve_reactor_with, ReactorConfig};
    use std::io::{BufRead, BufReader, Write};
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || {
        serve_reactor_with(
            pool,
            port,
            ReactorConfig { shards, ..ReactorConfig::default() },
        )
    });
    std::thread::sleep(Duration::from_millis(300));

    let mut joins = Vec::new();
    for c in 0..conns as u64 {
        let lines = lines as u64;
        joins.push(std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            stream.set_nodelay(true).unwrap();
            let mut batch = String::new();
            for i in 0..lines {
                let id = c * 1000 + i;
                // valid, missing-features and malformed lines -- every
                // reply is a pure function of the line itself
                match i % 4 {
                    3 => batch.push_str("garbage\n"),
                    2 => batch.push_str(&format!("{{\"id\":{id}}}\n")),
                    _ => batch.push_str(&format!(
                        "{{\"id\":{id},\"features\":[0.5,-0.5,0.25,1.0]}}\n"
                    )),
                }
            }
            stream.write_all(batch.as_bytes()).unwrap();
            let mut reader = BufReader::new(stream);
            let mut replies = Vec::new();
            for _ in 0..lines {
                let mut r = String::new();
                reader.read_line(&mut r).unwrap();
                replies.push(normalize_latency(r.trim().to_string()));
            }
            replies
        }));
    }
    let out: Vec<Vec<String>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let mut client = Client::connect(port).unwrap();
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
    out
}

/// Differential pin across shard counts: 40 pipelined connections get
/// byte-identical reply streams whether one event loop serves them all
/// or four shards split them, and replies stay FIFO per connection.
#[cfg(unix)]
#[test]
fn sharded_reactor_replies_match_single_shard_byte_for_byte() {
    let one = sharded_replies(1, 8016, 40, 16);
    let four = sharded_replies(4, 8017, 40, 16);
    assert_eq!(one, four, "replies must be byte-identical across shard counts");
    for (c, replies) in four.iter().enumerate() {
        let ids: Vec<u64> = replies
            .iter()
            .filter_map(|r| Json::parse(r).unwrap().get("id").as_u64())
            .collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "conn {c}: replies out of dispatch order");
        assert!(!ids.is_empty(), "conn {c}: no infer replies");
    }
}

/// Handoff drain pin: with 4 shards, 4 live connections land on 4
/// distinct shards (accepts all happen on shard 0, so at least 3 are
/// served on a shard they were not accepted on).  A shutdown pipelined
/// behind an infer on one of them must answer the infer first, ack,
/// and drain EVERY connection -- including the handed-off ones owned
/// by other shards -- to clean EOF promptly.
#[cfg(unix)]
#[test]
fn handed_off_connections_drain_cleanly_at_shutdown() {
    use abc_serve::server::reactor::{serve_reactor_with, ReactorConfig};
    use std::io::{BufRead, BufReader, Read, Write};
    let port = 8018;
    let pool = synthetic_pool(None);
    let server = std::thread::spawn(move || {
        serve_reactor_with(
            pool,
            port,
            ReactorConfig { shards: 4, ..ReactorConfig::default() },
        )
    });
    std::thread::sleep(Duration::from_millis(300));

    let mut streams: Vec<std::net::TcpStream> = (0..4)
        .map(|_| {
            let s = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            s.set_nodelay(true).unwrap();
            s
        })
        .collect();
    // every connection proves it is being served before the shutdown
    for (i, s) in streams.iter_mut().enumerate() {
        s.write_all(
            format!("{{\"id\":{i},\"features\":[0.5,-0.5,0.25,1.0]}}\n").as_bytes(),
        )
        .unwrap();
    }
    let mut readers: Vec<BufReader<std::net::TcpStream>> =
        streams.into_iter().map(BufReader::new).collect();
    for (i, r) in readers.iter_mut().enumerate() {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(
            Json::parse(line.trim()).unwrap().get("id").as_u64(),
            Some(i as u64),
            "conn {i}: {line:?}"
        );
    }
    // pipelined infer + shutdown on the last connection
    readers[3]
        .get_mut()
        .write_all(b"{\"id\":99,\"features\":[0.1,0.2,0.3,0.4]}\n{\"cmd\":\"shutdown\"}\n")
        .unwrap();
    let mut line = String::new();
    readers[3].read_line(&mut line).unwrap();
    assert_eq!(
        Json::parse(line.trim()).unwrap().get("id").as_u64(),
        Some(99),
        "infer line not answered before the ack: {line:?}"
    );
    line.clear();
    readers[3].read_line(&mut line).unwrap();
    assert!(line.contains("\"shutdown\":true"), "got {line:?}");
    let t0 = std::time::Instant::now();
    for (i, mut r) in readers.into_iter().enumerate() {
        let mut rest = String::new();
        r.read_to_string(&mut rest).unwrap();
        assert_eq!(rest.trim(), "", "conn {i}: bytes after drain");
    }
    server.join().unwrap().unwrap();
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "drain took {:?}",
        t0.elapsed()
    );
}

#[test]
fn geared_server_reports_active_gear_on_the_wire() {
    let port = 7994;
    // minimal one-gear plan; no controller needed to test the wire shape
    let plan = GearPlan::new(vec![abc_serve::planner::Gear {
        id: 0,
        k: 3,
        epsilon: 0.03,
        theta: 0.6,
        mid: vec![],
        max_batch: 8,
        replicas: 1,
        tier_fleet: vec![],
        dollar_per_req: 0.0,
        accuracy: 0.9,
        relative_cost: 1.0,
        sustainable_rps: 1000.0,
    }])
    .unwrap();
    let handle = GearHandle::new(plan.top().config());
    let pool = synthetic_pool(Some(Arc::clone(&handle)));
    let server = std::thread::spawn(move || serve(pool, port));
    std::thread::sleep(Duration::from_millis(300));

    let mut client = Client::connect(port).unwrap();
    let reply = client
        .roundtrip(r#"{"id": 1, "features": [0.5, 0.5, 0.5, 0.5]}"#)
        .unwrap();
    let parsed = Json::parse(&reply).unwrap();
    assert_eq!(parsed.get("gear").as_u64(), Some(0), "got {reply}");
    assert_eq!(parsed.get("id").as_u64(), Some(1));
    // the typed client still parses geared replies
    let (pred, exit_tier) = client.infer(2, &[0.1, 0.1, 0.1, 0.1]).unwrap();
    assert!(pred <= 1);
    assert!((1..=3).contains(&exit_tier));

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}
