//! Hot-path microbench: coordinator overhead on top of raw engine
//! execution -- full cascade batches, the serving pipeline, the batcher,
//! and the pure agreement/deferral logic.
//!
//! Run: `cargo bench --bench bench_coordinator`.

use std::sync::Arc;
use std::time::Duration;

use abc_serve::benchkit::{black_box, emit_json, Bench};
use abc_serve::calib;
use abc_serve::coordinator::agreement::agree_logits;
use abc_serve::coordinator::batcher::{Batcher, BatcherConfig, Item};
use abc_serve::coordinator::cascade::Cascade;
use abc_serve::coordinator::pipeline::Pipeline;
use abc_serve::metrics::Metrics;
use abc_serve::runtime::engine::Engine;
use abc_serve::types::{Class, Request, RuleKind};
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::rng::Rng;
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn main() -> anyhow::Result<()> {
    // pure logic first (no artifacts needed)
    let mut rng = Rng::new(1);
    let logits: Vec<f32> = (0..3 * 10).map(|_| rng.f32() * 6.0 - 3.0).collect();
    let mut b = Bench::new("coordinator: pure logic");
    b.run("agree_logits k=3 c=10", || black_box(agree_logits(&logits, 3, 10)));
    let big_logits: Vec<f32> = (0..5 * 100).map(|_| rng.f32() * 6.0 - 3.0).collect();
    b.run("agree_logits k=5 c=100", || black_box(agree_logits(&big_logits, 5, 100)));
    b.run("batcher push+flush 1024", || {
        let sink = Batcher::spawn(
            BatcherConfig { max_batch: 64, max_wait: Duration::from_micros(200) },
            |batch: Vec<Item<u32>>| {
                black_box(batch.len());
            },
        );
        for i in 0..1024u32 {
            sink.push(i).unwrap();
        }
        drop(sink); // drains
    });
    b.report();
    let mut groups = vec![b.to_json()];
    let emit = |groups: Vec<Json>| -> anyhow::Result<()> {
        let mut o = JsonObj::new();
        o.insert("bench", Json::str("coordinator"));
        o.insert("groups", Json::Arr(groups));
        emit_json("coordinator", Json::Obj(o))?;
        Ok(())
    };

    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping cascade benches: run `make artifacts` first");
            return emit(groups);
        }
    };
    let engine = Arc::new(Engine::cpu()?);
    let rt = Arc::new(SuiteRuntime::load(engine, &manifest, "synth-cifar10", false)?);
    let val = rt.dataset(&manifest, "val")?;
    let test = rt.dataset(&manifest, "test")?;
    let cal = calib::calibrate(&rt.tiers, RuleKind::MeanScore, &val, 100, 0.05)?;
    let cascade = Arc::new(Cascade::new(rt.tiers.clone(), cal.policy.clone()));

    let mut b = Bench::new("coordinator: cascade classify_batch");
    for &n in &[1usize, 32, 128, 512] {
        let data = &test.x[..n * test.dim];
        let r = b.run(format!("batch {n}"), || {
            black_box(cascade.classify_batch(data, n).unwrap())
        });
        println!("batch {n}: {:.0} samples/s", n as f64 / r.mean_s);
    }
    b.report();
    groups.push(b.to_json());

    // end-to-end pipeline (batcher + cascade + verdict channels)
    let pipeline = Arc::new(Pipeline::spawn(
        Arc::clone(&cascade),
        BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(1) },
        Metrics::new(),
    ));
    let mut b = Bench::new("coordinator: serving pipeline");
    b.run("single blocking infer", || {
        black_box(
            pipeline
                .infer(Request {
                    id: 0,
                    features: test.row(0).to_vec(),
                    arrival_s: 0.0,
                    class: Class::Standard,
                })
                .unwrap(),
        )
    });
    b.run("64 concurrent submits", || {
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                pipeline
                    .submit(Request {
                        id: i,
                        features: test.row(i as usize % test.n).to_vec(),
                        arrival_s: 0.0,
                        class: Class::Standard,
                    })
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            black_box(rx.recv().unwrap().unwrap());
        }
    });
    b.report();
    groups.push(b.to_json());
    emit(groups)
}
