//! SLO-observatory bench: what does weighted-fair admission buy the
//! premium class when batch traffic floods the pool?
//!
//! Replays the same on-off trace (bursts at 2x the pool's nominal
//! saturation, class mix 70% batch / 20% standard / 10% premium)
//! against two identical pools that differ only in admission: plain
//! FIFO (class-blind) vs weighted-fair quotas.  Both runs keep
//! per-class books in the SLO observatory; the table shows each class's
//! submitted/completed/shed ledger, cumulative attainment, windowed p99
//! and goodput, and the acceptance bar is **premium p99 SLO holds under
//! the batch burst with fair quotas** while aggregate goodput stays
//! within a few percent of FIFO.
//!
//! `BENCH_slo.json` carries the same machine-readably for the CI trend
//! gate.
//!
//! Run: `cargo bench --bench bench_slo`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::obs::slo::{SloConfig, SloObservatory, SloStatus};
use abc_serve::trafficgen::{LoadGen, LoadReport, SyntheticClassifier, Trace};
use abc_serve::types::Class;
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::table::Table;

const DIM: usize = 8;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 32;
const REPLICAS: usize = 2;
const PER_ROW: Duration = Duration::from_millis(2); // ~500 rows/s/replica
/// premium / standard / batch offered shares: batch dominates the wire.
const MIX: [f64; Class::COUNT] = [0.1, 0.2, 0.7];
/// premium / standard / batch admission weights for the fair case.
const WEIGHTS: [f64; Class::COUNT] = [0.6, 0.3, 0.1];
const N_REQUESTS: usize = 6000;
const WORKERS: usize = 192;

fn classifier() -> SyntheticClassifier {
    SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW)
}

fn slo_cfg() -> SloConfig {
    // premium 250ms against a ~64ms nominal full-queue drain; the burn
    // windows comfortably cover the whole run
    SloConfig { targets_s: [0.25, 1.0, 10.0], ..SloConfig::default() }
}

fn onoff_trace() -> Arc<Trace> {
    let rate = 2.0 * REPLICAS as f64 * classifier().capacity_rps(MAX_BATCH);
    Arc::new(Trace::synth(
        Arrival::OnOff { rate, on_s: 0.4, off_s: 0.5 },
        N_REQUESTS,
        DIM,
        59,
    ))
}

fn run_case(
    weights: Option<[f64; Class::COUNT]>,
    trace: Arc<Trace>,
) -> (LoadReport, Vec<SloStatus>) {
    let metrics = Metrics::new();
    let pool = Arc::new(ReplicaPool::spawn(
        Arc::new(classifier()),
        PoolConfig {
            replicas: REPLICAS,
            max_queue: MAX_QUEUE,
            batcher: BatcherConfig {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_millis(1),
            },
            class_weights: weights,
            ..PoolConfig::default()
        },
        Arc::clone(&metrics),
    ));
    let slo = SloObservatory::new(slo_cfg(), &metrics);
    pool.attach_slo(Arc::clone(&slo));
    let started = Instant::now();
    let report = LoadGen { workers: WORKERS, class_mix: Some(MIX) }
        .run(&pool, trace, &Metrics::new())
        .expect("load run");
    // one deterministic tick over the whole run: the windowed p99 and
    // goodput below summarize everything that happened
    slo.tick(started.elapsed().as_secs_f64());
    (report, slo.statuses())
}

fn main() {
    let trace = onoff_trace();
    println!(
        "on-off trace: {} requests, bursts at 2x saturation, class mix \
         premium/standard/batch = {MIX:?}; admission: FIFO vs \
         weighted-fair {WEIGHTS:?}",
        trace.len(),
    );

    let cases: [(&str, Option<[f64; Class::COUNT]>); 2] =
        [("fifo", None), ("fair-quota", Some(WEIGHTS))];
    let runs: Vec<(&str, LoadReport, Vec<SloStatus>)> = cases
        .into_iter()
        .map(|(name, w)| {
            let (report, statuses) = run_case(w, Arc::clone(&trace));
            (name, report, statuses)
        })
        .collect();

    let mut table = Table::new(
        "per-class SLO books (same trace, admission policy varies)",
        &["config", "class", "target", "submitted", "done", "shed",
          "attainment", "p99", "goodput rps"],
    );
    for (name, _, statuses) in &runs {
        for s in statuses {
            table.row(vec![
                name.to_string(),
                s.class.name().to_string(),
                abc_serve::benchkit::fmt_time(s.target_s),
                s.submitted.to_string(),
                s.completed.to_string(),
                s.shed.to_string(),
                format!("{:.3}", s.attainment),
                abc_serve::benchkit::fmt_time(s.p99_s),
                format!("{:.0}", s.goodput_rps),
            ]);
        }
    }
    println!("{}", table.render());

    let premium = Class::Premium.index();
    let fifo_premium = &runs[0].2[premium];
    let fair_premium = &runs[1].2[premium];
    let goal = slo_cfg().goal;
    let target = slo_cfg().targets_s[premium];
    let p99_holds = fair_premium.p99_s <= target;
    let attainment_holds = fair_premium.attainment >= goal;
    let goodput_ratio =
        runs[1].1.goodput_rps / runs[0].1.goodput_rps.max(1e-9);
    println!(
        "premium attainment: FIFO {:.3} vs fair {:.3} (goal {goal});  \
         premium p99: FIFO {} vs fair {} (target {})",
        fifo_premium.attainment,
        fair_premium.attainment,
        abc_serve::benchkit::fmt_time(fifo_premium.p99_s),
        abc_serve::benchkit::fmt_time(fair_premium.p99_s),
        abc_serve::benchkit::fmt_time(target),
    );
    println!(
        "aggregate goodput: fair = {:.1}% of FIFO.",
        100.0 * goodput_ratio
    );
    println!(
        "verdict: premium p99 SLO holds under batch burst: {}",
        if p99_holds && attainment_holds { "YES" } else { "NO" },
    );

    let mut o = JsonObj::new();
    o.insert("bench", Json::str("slo"));
    let class_json = |s: &SloStatus| {
        let mut c = JsonObj::new();
        c.insert("class", Json::str(s.class.name()));
        c.insert("target_s", Json::num(s.target_s));
        c.insert("submitted", Json::num(s.submitted as f64));
        c.insert("completed", Json::num(s.completed as f64));
        c.insert("shed", Json::num(s.shed as f64));
        c.insert("attainment", Json::num(s.attainment));
        c.insert("p99_s", Json::num(s.p99_s));
        c.insert("goodput_rps", Json::num(s.goodput_rps));
        Json::Obj(c)
    };
    let case_json = |name: &str, r: &LoadReport, statuses: &[SloStatus]| {
        let mut c = JsonObj::new();
        c.insert("config", Json::str(name));
        c.insert("classes", Json::Arr(statuses.iter().map(class_json).collect()));
        c.insert("report", r.to_json());
        Json::Obj(c)
    };
    o.insert(
        "cases",
        Json::Arr(
            runs.iter().map(|(name, r, s)| case_json(name, r, s)).collect(),
        ),
    );
    o.insert("premium_attainment_fifo", Json::num(fifo_premium.attainment));
    o.insert("premium_attainment_fair", Json::num(fair_premium.attainment));
    o.insert("goodput_ratio_fair", Json::num(goodput_ratio));
    o.insert("premium_slo_holds", Json::Bool(p99_holds && attainment_holds));
    abc_serve::benchkit::emit_json("slo", Json::Obj(o)).expect("emit json");
}
