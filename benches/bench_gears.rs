//! Serving-scale bench: fixed gears vs the adaptive controller under
//! on-off load.
//!
//! Replays the same on-off trace (bursts at 2x the top gear's
//! saturation) against three configurations of one replica pool:
//!
//! * **fixed top** -- the accuracy-first gear, pinned: sheds heavily
//!   during bursts;
//! * **fixed fast** -- the throughput gear, pinned: survives the bursts
//!   by paying its accuracy cost on *every* request, including the idle
//!   majority of the trace;
//! * **adaptive** -- the online controller downshifting into bursts and
//!   upshifting out of them.
//!
//! The rendered table shows goodput, sheds and the *goodput-weighted
//! expected accuracy* (completed requests served at each gear's planned
//! accuracy): the adaptive row should match the fast gear's goodput
//! while holding accuracy near the top gear's, which is the entire
//! point of the subsystem.
//!
//! Run: `cargo bench --bench bench_gears`.

use std::sync::Arc;
use std::time::Duration;

use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::control::{ControlConfig, ControlLoop, ControlTarget, ControllerConfig};
use abc_serve::planner::{Gear, GearHandle, GearPlan};
use abc_serve::trafficgen::{LoadGen, LoadReport, SyntheticClassifier, Trace};
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::table::{fnum, Table};

const DIM: usize = 8;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 16;
const PER_ROW: Duration = Duration::from_millis(2); // top gear ~500 rows/s
const FAST_WORK: f64 = 0.25;
const N_REQUESTS: usize = 800;

fn classifier() -> Arc<SyntheticClassifier> {
    Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW))
}

fn plan() -> GearPlan {
    let cap = classifier().capacity_rps(MAX_BATCH);
    let gear = |acc: f64, work: f64| Gear {
        id: 0,
        k: 3,
        epsilon: 0.03,
        theta: 0.6,
        mid: vec![],
        max_batch: MAX_BATCH,
        replicas: 1,
        tier_fleet: vec![],
        dollar_per_req: 0.0,
        accuracy: acc,
        relative_cost: work,
        sustainable_rps: cap / work,
    };
    GearPlan::new(vec![gear(0.95, 1.0), gear(0.85, FAST_WORK)]).unwrap()
}

fn pool_cfg() -> PoolConfig {
    PoolConfig {
        replicas: 1,
        max_queue: MAX_QUEUE,
        batcher: BatcherConfig {
            max_batch: MAX_BATCH,
            max_wait: Duration::from_millis(1),
        },
        ..PoolConfig::default()
    }
}

fn onoff_trace() -> Arc<Trace> {
    let rate = 2.0 * classifier().capacity_rps(MAX_BATCH);
    Arc::new(Trace::synth(
        Arrival::OnOff { rate, on_s: 0.3, off_s: 0.3 },
        N_REQUESTS,
        DIM,
        23,
    ))
}

/// Run the trace against a pool pinned to one gear of the plan.
fn run_fixed(plan: &GearPlan, gear_idx: usize, trace: Arc<Trace>) -> LoadReport {
    let handle = GearHandle::new(plan.gears[gear_idx].config());
    let pool = Arc::new(ReplicaPool::spawn_geared(
        classifier(),
        pool_cfg(),
        Metrics::new(),
        handle,
    ));
    LoadGen { workers: 64, class_mix: None }
        .run(&pool, trace, &Metrics::new())
        .expect("fixed-gear run")
}

/// Run the trace with the online controller engaged; returns the load
/// report plus the (down, up) shift counts from the shared registry.
fn run_adaptive(plan: &GearPlan, trace: Arc<Trace>) -> (LoadReport, u64, u64) {
    let handle = GearHandle::new(plan.top().config());
    let metrics = Metrics::new();
    let pool = Arc::new(ReplicaPool::spawn_geared(
        classifier(),
        pool_cfg(),
        Arc::clone(&metrics),
        Arc::clone(&handle),
    ));
    let _controller = ControlLoop::spawn(
        Arc::clone(&pool) as Arc<dyn ControlTarget>,
        ControlConfig::gear_plan(
            plan.clone(),
            ControllerConfig {
                sample_every: Duration::from_millis(10),
                dwell: Duration::from_millis(200),
                ..ControllerConfig::default()
            },
        ),
    );
    let report = LoadGen { workers: 64, class_mix: None }
        .run(&pool, trace, &Metrics::new())
        .expect("adaptive run");
    let down = metrics.counter("gear_shift_down").get();
    let up = metrics.counter("gear_shift_up").get();
    (report, down, up)
}

fn main() {
    let plan = plan();
    let trace = onoff_trace();
    println!(
        "on-off trace: {} requests, bursts at {:.0} rps (2x top gear's {:.0}), \
         {} gears: {}",
        trace.len(),
        2.0 * classifier().capacity_rps(MAX_BATCH),
        classifier().capacity_rps(MAX_BATCH),
        plan.len(),
        plan.gears
            .iter()
            .map(|g| format!(
                "#{} acc {:.2} @ {:.0} rps",
                g.id, g.accuracy, g.sustainable_rps
            ))
            .collect::<Vec<_>>()
            .join(", "),
    );

    let top = run_fixed(&plan, 0, Arc::clone(&trace));
    let fast = run_fixed(&plan, plan.len() - 1, Arc::clone(&trace));
    let (adaptive, down, up) = run_adaptive(&plan, Arc::clone(&trace));

    // goodput-weighted expected accuracy: every completed request counts
    // at its serving gear's planned accuracy, sheds count 0.  Fixed
    // gears serve everything at one accuracy; for the adaptive run,
    // bound it conservatively by assuming every downshifted batch ran
    // at the fastest gear's accuracy (true mix is better).
    let weighted = |completed: u64, acc: f64| completed as f64 * acc;
    let top_q = weighted(top.completed, plan.top().accuracy);
    let fast_q = weighted(fast.completed, plan.fastest().accuracy);
    let adaptive_q_lower = weighted(adaptive.completed, plan.fastest().accuracy);
    let adaptive_q_upper = weighted(adaptive.completed, plan.top().accuracy);

    let mut table = Table::new(
        "fixed vs adaptive under on-off load (2x top-gear saturation)",
        &["config", "done", "shed", "err", "goodput rps", "p99", "quality (done x acc)"],
    );
    let mut row = |name: &str, r: &LoadReport, q: String| {
        table.row(vec![
            name.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            format!("{:.0}", r.goodput_rps),
            abc_serve::benchkit::fmt_time(r.p99_s),
            q,
        ]);
    };
    row("fixed top (accuracy-first)", &top, fnum(top_q, 0));
    row("fixed fast (throughput-first)", &fast, fnum(fast_q, 0));
    row(
        "adaptive (controller)",
        &adaptive,
        format!("{}..{}", fnum(adaptive_q_lower, 0), fnum(adaptive_q_upper, 0)),
    );
    println!("{}", table.render());
    println!(
        "controller shifted down {down}x / up {up}x.  reading the table: the \
         adaptive row should complete ~everything (like fixed fast, unlike \
         fixed top which sheds the burst excess) while its quality range sits \
         above fixed fast because idle stretches are served at the top gear."
    );

    let case = |name: &str, r: &LoadReport, q_lo: f64, q_hi: f64| {
        let mut o = JsonObj::new();
        o.insert("config", Json::str(name));
        o.insert("quality_lower", Json::num(q_lo));
        o.insert("quality_upper", Json::num(q_hi));
        o.insert("report", r.to_json());
        Json::Obj(o)
    };
    let mut o = JsonObj::new();
    o.insert("bench", Json::str("gears"));
    o.insert(
        "cases",
        Json::Arr(vec![
            case("fixed_top", &top, top_q, top_q),
            case("fixed_fast", &fast, fast_q, fast_q),
            case("adaptive", &adaptive, adaptive_q_lower, adaptive_q_upper),
        ]),
    );
    o.insert("shifts_down", Json::num(down as f64));
    o.insert("shifts_up", Json::num(up as f64));
    abc_serve::benchkit::emit_json("gears", Json::Obj(o)).expect("emit json");
}
