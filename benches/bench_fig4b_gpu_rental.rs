//! Regenerates paper fig4b (see DESIGN.md §5 experiment index) and
//! reports the wall-clock of the full regeneration.
//!
//! Run: `cargo bench --bench bench_fig4b_gpu_rental` (or `make bench`).

use abc_serve::experiments::{self, common::ExpContext};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("ABC_BENCH_QUICK").is_ok();
    let ctx = ExpContext::new("artifacts", "artifacts/results", quick)?;
    let t0 = std::time::Instant::now();
    experiments::run("fig4b", &ctx)?;
    println!(
        "[bench_fig4b_gpu_rental] regenerated fig4b in {:.2}s{}",
        t0.elapsed().as_secs_f64(),
        if quick { " (quick mode)" } else { "" }
    );
    Ok(())
}
