//! Drift-observatory overhead bench: what does shadow sampling cost
//! the serving path?
//!
//! Replays the same on-off trace (bursts at 2x the fleet's nominal
//! saturation) against three identical tiered fleets that differ only
//! in the shadow-sample rate: off, 1-in-100 (the production default)
//! and 1-in-10 (aggressive).  Shadowed rows re-run the next tier off
//! the critical path, so the client-visible cost should be only the
//! extra offered load at the downstream tiers; the acceptance bar is
//! **shadow-100 goodput within 5% of shadow-off**.
//!
//! The table shows goodput, p99 and the shadow ledger (submitted /
//! dropped / shed / scored) per case, and `BENCH_drift.json` carries
//! the same machine-readably for the CI trend gate.
//!
//! Run: `cargo bench --bench bench_drift`.

use std::sync::Arc;
use std::time::Duration;

use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::StageClassifier;
use abc_serve::coordinator::router::{TierSpec, TieredFleet, TieredFleetConfig};
use abc_serve::cost::rental::Gpu;
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::obs::DriftConfig;
use abc_serve::trafficgen::{
    LoadGen, LoadReport, StagedSynthetic, SyntheticClassifier, Trace,
};
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::table::Table;

const DIM: usize = 8;
const LEVELS: usize = 3;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 32;
const PER_ROW: Duration = Duration::from_millis(2); // ~500 rows/s/replica
const WEIGHTS: [f64; 3] = [0.15, 0.25, 0.60];
const N_REQUESTS: usize = 6000;
const WORKERS: usize = 192;

fn inner() -> SyntheticClassifier {
    SyntheticClassifier::new(DIM, LEVELS, Duration::ZERO, PER_ROW)
}

fn onoff_trace() -> Arc<Trace> {
    let rate = 2.0 * 4.0 * inner().capacity_rps(MAX_BATCH);
    Arc::new(Trace::synth(
        Arrival::OnOff { rate, on_s: 0.4, off_s: 0.5 },
        N_REQUESTS,
        DIM,
        53,
    ))
}

struct ShadowLedger {
    submitted: u64,
    dropped: u64,
    shed: u64,
    scored: u64,
}

fn run_case(sample_every: u64, trace: Arc<Trace>) -> (LoadReport, ShadowLedger) {
    let stage = Arc::new(StagedSynthetic::new(inner(), WEIGHTS.to_vec()));
    let metrics = Metrics::new();
    let drift = (sample_every > 0)
        .then(|| DriftConfig { sample_every, ..DriftConfig::default() });
    let fleet = Arc::new(
        TieredFleet::spawn_with_drift(
            stage as Arc<dyn StageClassifier>,
            TieredFleetConfig {
                tiers: vec![
                    TierSpec::fixed(Gpu::V100, 2, MAX_QUEUE),
                    TierSpec::fixed(Gpu::A6000, 2, MAX_QUEUE),
                    TierSpec::fixed(Gpu::H100, 1, MAX_QUEUE),
                ],
                batcher: BatcherConfig {
                    max_batch: MAX_BATCH,
                    max_wait: Duration::from_millis(1),
                },
                class_weights: None,
            },
            Arc::clone(&metrics),
            None,
            drift,
        )
        .expect("fleet spawn"),
    );
    let report = LoadGen { workers: WORKERS, class_mix: None }
        .run(&fleet, trace, &Metrics::new())
        .expect("load run");
    let scored = fleet
        .drift()
        .map(|m| (0..m.n_tiers()).map(|t| m.status(t).unwrap().samples).sum())
        .unwrap_or(0);
    let ledger = ShadowLedger {
        submitted: metrics.counter("shadow_submitted").get(),
        dropped: metrics.counter("shadow_dropped").get(),
        shed: metrics.counter("shadow_shed").get(),
        scored,
    };
    (report, ledger)
}

fn main() {
    let trace = onoff_trace();
    println!(
        "on-off trace: {} requests, bursts at 2x saturation, cascade \
         weights {WEIGHTS:?}; shadow rates: off vs 1-in-100 vs 1-in-10",
        trace.len(),
    );

    let cases: [(&str, u64); 3] =
        [("shadow-off", 0), ("shadow-100", 100), ("shadow-10", 10)];
    let runs: Vec<(&str, u64, LoadReport, ShadowLedger)> = cases
        .into_iter()
        .map(|(name, n)| {
            let (report, ledger) = run_case(n, Arc::clone(&trace));
            (name, n, report, ledger)
        })
        .collect();

    let mut table = Table::new(
        "drift observatory overhead (same fleet, shadow rate varies)",
        &["config", "done", "shed", "goodput rps", "p99", "shadow sub",
          "shadow drop", "shadow shed", "scored"],
    );
    for (name, _, r, l) in &runs {
        table.row(vec![
            name.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            format!("{:.0}", r.goodput_rps),
            abc_serve::benchkit::fmt_time(r.p99_s),
            l.submitted.to_string(),
            l.dropped.to_string(),
            l.shed.to_string(),
            l.scored.to_string(),
        ]);
    }
    println!("{}", table.render());

    let off = runs[0].2.goodput_rps.max(1e-9);
    let ratio_100 = runs[1].2.goodput_rps / off;
    let ratio_10 = runs[2].2.goodput_rps / off;
    println!(
        "shadow-100 goodput = {:.1}% of off;  shadow-10 = {:.1}% of off.",
        100.0 * ratio_100,
        100.0 * ratio_10,
    );
    println!(
        "verdict: shadow-100 within 5% of off: {}",
        if ratio_100 >= 0.95 { "YES" } else { "NO" },
    );

    let mut o = JsonObj::new();
    o.insert("bench", Json::str("drift"));
    let case_json = |name: &str, n: u64, r: &LoadReport, l: &ShadowLedger| {
        let mut c = JsonObj::new();
        c.insert("config", Json::str(name));
        c.insert("sample_every", Json::num(n as f64));
        c.insert("shadow_submitted", Json::num(l.submitted as f64));
        c.insert("shadow_dropped", Json::num(l.dropped as f64));
        c.insert("shadow_shed", Json::num(l.shed as f64));
        c.insert("shadow_scored", Json::num(l.scored as f64));
        c.insert("report", r.to_json());
        Json::Obj(c)
    };
    o.insert(
        "cases",
        Json::Arr(
            runs.iter().map(|(name, n, r, l)| case_json(name, *n, r, l)).collect(),
        ),
    );
    o.insert("goodput_ratio_100", Json::num(ratio_100));
    o.insert("goodput_ratio_10", Json::num(ratio_10));
    o.insert("shadow_100_within_5pct", Json::Bool(ratio_100 >= 0.95));
    abc_serve::benchkit::emit_json("drift", Json::Obj(o)).expect("emit json");
}
