//! Regenerates paper fig6 (see DESIGN.md §5 experiment index) and
//! reports the wall-clock of the full regeneration.
//!
//! Run: `cargo bench --bench bench_fig6_threshold` (or `make bench`).

use abc_serve::experiments::{self, common::ExpContext};
use abc_serve::util::json::{Json, JsonObj};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("ABC_BENCH_QUICK").is_ok();
    let ctx = ExpContext::new("artifacts", "artifacts/results", quick)?;
    let t0 = std::time::Instant::now();
    experiments::run("fig6", &ctx)?;
    let wall_s = t0.elapsed().as_secs_f64();
    println!(
        "[bench_fig6_threshold] regenerated fig6 in {wall_s:.2}s{}",
        if quick { " (quick mode)" } else { "" }
    );
    let mut o = JsonObj::new();
    o.insert("bench", Json::str("fig6_threshold"));
    o.insert("exp", Json::str("fig6"));
    o.insert("wall_s", Json::num(wall_s));
    o.insert("quick", Json::Bool(quick));
    abc_serve::benchkit::emit_json("fig6_threshold", Json::Obj(o))?;
    Ok(())
}
