//! Serving-scale bench: goodput vs offered load across replica counts.
//!
//! Runs the open-loop trafficgen against `ReplicaPool`s over the
//! synthetic backend (no artifacts needed), sweeping offered load from
//! well below to well past saturation for 1 / 2 / 4 replicas.  The
//! rendered tables show the two shapes the subsystem exists to measure:
//!
//! * the p99 latency knee moves right as replicas are added -- a
//!   4-replica pool sustains ~4x the offered load of 1 replica before
//!   latency departs from the service floor;
//! * past saturation, goodput plateaus at pool capacity and the excess
//!   is shed (`Overloaded`) instead of growing queues without bound.
//!
//! Run: `cargo bench --bench bench_loadgen`.

use std::sync::Arc;
use std::time::Duration;

use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::trafficgen::{LoadGen, LoadReport, SyntheticClassifier, Trace};
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::table::Table;

const DIM: usize = 8;
const MAX_BATCH: usize = 8;
const PER_ROW: Duration = Duration::from_millis(2); // 1 replica ~500 rows/s
const MAX_QUEUE: usize = 32;
const RUN_S: f64 = 0.4;

fn run_point(replicas: usize, offered_rps: f64) -> LoadReport {
    let classifier = Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW));
    let pool = Arc::new(ReplicaPool::spawn(
        classifier,
        PoolConfig {
            replicas,
            max_queue: MAX_QUEUE,
            batcher: BatcherConfig {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_millis(1),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
    ));
    let n = (offered_rps * RUN_S).max(32.0) as usize;
    let trace = Arc::new(Trace::synth(
        Arrival::Poisson { rate: offered_rps },
        n,
        DIM,
        7 + replicas as u64,
    ));
    let workers = (replicas * MAX_QUEUE * 2).clamp(32, 512);
    LoadGen { workers, class_mix: None }
        .run(&pool, trace, &Metrics::new())
        .expect("load run")
}

fn main() {
    let single_capacity =
        SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW).capacity_rps(MAX_BATCH);
    println!(
        "synthetic backend: {:.0} rows/s per replica at batch {MAX_BATCH} \
         ({} per row), max-queue {MAX_QUEUE}/replica\n",
        single_capacity,
        abc_serve::benchkit::fmt_time(PER_ROW.as_secs_f64()),
    );

    // offered load as multiples of ONE replica's capacity
    let load_factors = [0.5, 1.0, 2.0, 4.0, 6.0];
    let mut cases = Vec::new();
    for replicas in [1usize, 2, 4] {
        let mut table = Table::new(
            format!("{replicas} replica(s): goodput vs offered load"),
            LoadReport::header(),
        );
        for f in load_factors {
            let report = run_point(replicas, f * single_capacity);
            table.row(report.row_cells());
            let mut o = JsonObj::new();
            o.insert("replicas", Json::num(replicas as f64));
            o.insert("load_factor", Json::num(f));
            o.insert("report", report.to_json());
            cases.push(Json::Obj(o));
        }
        println!("{}", table.render());
    }
    println!(
        "reading the curve: goodput tracks offered load until ~capacity, \
         then plateaus with the excess shed; the p99 knee shifts right \
         with each doubling of replicas."
    );
    let mut o = JsonObj::new();
    o.insert("bench", Json::str("loadgen"));
    o.insert("cases", Json::Arr(cases));
    abc_serve::benchkit::emit_json("loadgen", Json::Obj(o)).expect("emit json");
}
