//! Hot-path microbench: raw PJRT engine execution across tiers and batch
//! buckets -- the L3 roofline reference (DESIGN.md §8: the coordinator
//! must stay within 0.8x of this).
//!
//! Run: `cargo bench --bench bench_engine`.

use std::sync::Arc;

use abc_serve::benchkit::{black_box, emit_json, Bench};
use abc_serve::runtime::engine::Engine;
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::zoo::manifest::Manifest;
use abc_serve::zoo::registry::SuiteRuntime;

fn main() -> anyhow::Result<()> {
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping bench_engine: run `make artifacts` first");
            return Ok(());
        }
    };
    let engine = Arc::new(Engine::cpu()?);
    let rt = SuiteRuntime::load(engine, &manifest, "synth-cifar10", true)?;
    let test = rt.dataset(&manifest, "test")?;

    let mut b = Bench::new("engine: tier ensemble execute (per batch)");
    for (idx, tier) in rt.tiers.iter().enumerate() {
        for &bucket in &[1usize, 8, 32, 128] {
            let data = &test.x[..bucket * test.dim];
            b.run(format!("t{} b{bucket}", idx + 1), || {
                black_box(tier.run(data, bucket).unwrap())
            });
        }
    }
    b.report();

    let mut b2 = Bench::new("engine: per-sample throughput (batch 128)");
    for (idx, tier) in rt.tiers.iter().enumerate() {
        let data = &test.x[..128 * test.dim];
        let r = b2.run(format!("t{}", idx + 1), || {
            black_box(tier.run(data, 128).unwrap())
        });
        println!(
            "t{}: {:.0} samples/s",
            idx + 1,
            128.0 / r.mean_s
        );
    }
    b2.report();

    // single-model artifact for comparison
    let mut b3 = Bench::new("engine: single-model execute (batch 128)");
    for (idx, single) in rt.singles.iter().enumerate() {
        let data = &test.x[..128 * test.dim];
        b3.run(format!("t{}", idx + 1), || {
            black_box(single.run_single(data, 128).unwrap())
        });
    }
    b3.report();

    let mut o = JsonObj::new();
    o.insert("bench", Json::str("engine"));
    o.insert("groups", Json::Arr(vec![b.to_json(), b2.to_json(), b3.to_json()]));
    emit_json("engine", Json::Obj(o))?;
    Ok(())
}
