//! Frontend bench: the event-driven reactor vs the thread-per-connection
//! path (the ISSUE 9 acceptance bar).
//!
//! Drives hundreds of concurrent closed-loop connections against the
//! same synthetic pool behind each frontend and compares:
//!
//! * **connections per server thread** -- the threaded frontend spends
//!   one OS thread per client (+1 acceptor); the reactor spends one
//!   event loop + a worker pool sized to cores regardless of client
//!   count.  The bar: the reactor sustains >= 10x the connections per
//!   server thread;
//! * **goodput** -- answered roundtrips per second; the reactor must
//!   hold >= 95% of the threaded frontend's goodput at the same
//!   connection count;
//! * **p50/p99 roundtrip latency** for the record.
//!
//! A micro group times the wire-decode paths themselves: the lazy
//! `scan_request_line` (no JSON tree) vs the eager `parse_request_line`
//! on a representative infer line.
//!
//! Run: `cargo bench --bench bench_frontend`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use abc_serve::benchkit::{black_box, emit_json, Bench};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::metrics::Metrics;
use abc_serve::server::proto::{parse_request_line, scan_request_line};
use abc_serve::server::{serve_with, Client, Frontend, InferReply};
use abc_serve::trafficgen::SyntheticClassifier;
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::stats::Samples;
use abc_serve::util::table::Table;

const DIM: usize = 8;
const PER_ROW: Duration = Duration::from_micros(50);
const RUN: Duration = Duration::from_secs(2);

fn pool() -> Arc<ReplicaPool> {
    Arc::new(ReplicaPool::spawn(
        Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW)),
        PoolConfig {
            replicas: 1,
            // admission must hold every connection's in-flight line:
            // the bench measures the frontends, not the shed policy
            max_queue: 1024,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(1),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
    ))
}

struct Drive {
    goodput_rps: f64,
    p50_s: f64,
    p99_s: f64,
    answered: u64,
}

/// Closed-loop load: `conns` client threads, each ping-ponging infer
/// roundtrips until the deadline.  Returns goodput over the measured
/// window and the merged latency quantiles.
fn drive(frontend: Frontend, port: u16, conns: usize) -> Drive {
    let server_pool = pool();
    let server = std::thread::spawn(move || serve_with(server_pool, port, frontend));
    std::thread::sleep(Duration::from_millis(300));

    let answered = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let deadline = t0 + RUN;
    let clients: Vec<_> = (0..conns)
        .map(|c| {
            let answered = Arc::clone(&answered);
            std::thread::spawn(move || {
                let mut client = Client::connect(port).expect("connect");
                let feats: Vec<f32> =
                    (0..DIM).map(|i| (c + i) as f32 * 0.01).collect();
                let mut lat = Vec::new();
                let mut id = (c as u64) << 32;
                while Instant::now() < deadline {
                    let sent = Instant::now();
                    match client.infer_reply(id, &feats) {
                        Ok(InferReply::Verdict(_)) => {
                            lat.push(sent.elapsed().as_secs_f64());
                            answered.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(InferReply::Overloaded { .. }) => {}
                        Err(_) => break,
                    }
                    id += 1;
                }
                lat
            })
        })
        .collect();
    let mut samples = Samples::new();
    for c in clients {
        samples.extend(&c.join().expect("client thread"));
    }
    let elapsed = t0.elapsed().as_secs_f64();

    let mut stopper = Client::connect(port).expect("connect for shutdown");
    stopper.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("serve");

    let answered = answered.load(Ordering::Relaxed);
    Drive {
        goodput_rps: answered as f64 / elapsed,
        p50_s: samples.p50(),
        p99_s: samples.p99(),
        answered,
    }
}

fn main() {
    // wire-decode micro: what one line costs on each path
    let line = r#"{"id":123,"features":[0.125,-0.5,0.25,1.0,0.75,-0.125,0.0625,2.0],"class":"premium"}"#;
    const OPS: usize = 1000;
    let mut micro = Bench::new("frontend: wire decode (x1000 per iter)");
    micro.run("scan_request_line (lazy)", || {
        for _ in 0..OPS {
            black_box(scan_request_line(black_box(line)).is_ok());
        }
    });
    micro.run("parse_request_line (tree)", || {
        for _ in 0..OPS {
            black_box(parse_request_line(black_box(line)).is_ok());
        }
    });
    micro.report();

    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let conns = (12 * (workers + 1)).clamp(120, 480);
    // server-side thread budget at `conns` connections
    let threads_threads = conns + 1; // one handler per client + acceptor
    let reactor_threads = workers + 1; // worker pool + the event loop
    println!(
        "closed loop: {conns} connections x {:.0?} against 1 replica \
         ({workers} reactor workers)\n",
        RUN
    );

    let threaded = drive(Frontend::Threads, 8117, conns);
    let reactor = drive(Frontend::Reactor, 8118, conns);

    let mut table = Table::new(
        "frontend comparison",
        &["frontend", "conns", "srv threads", "conns/thread", "goodput r/s", "p50 ms", "p99 ms"],
    );
    for (name, threads, d) in [
        ("threads", threads_threads, &threaded),
        ("reactor", reactor_threads, &reactor),
    ] {
        table.row(vec![
            name.to_string(),
            format!("{conns}"),
            format!("{threads}"),
            format!("{:.1}", conns as f64 / threads as f64),
            format!("{:.0}", d.goodput_rps),
            format!("{:.2}", d.p50_s * 1e3),
            format!("{:.2}", d.p99_s * 1e3),
        ]);
    }
    println!("{}", table.render());

    let ratio_conns = (conns as f64 / reactor_threads as f64)
        / (conns as f64 / threads_threads as f64);
    let ratio_goodput = reactor.goodput_rps / threaded.goodput_rps.max(1e-9);
    println!(
        "reactor vs threads: {ratio_conns:.1}x connections per server \
         thread at {:.1}% goodput",
        100.0 * ratio_goodput
    );
    let verdict = ratio_conns >= 10.0 && ratio_goodput >= 0.95;
    println!(
        "verdict: reactor >= 10x connections/thread at >= 95% goodput: {}",
        if verdict { "YES" } else { "NO" },
    );

    let case = |name: &str, threads: usize, d: &Drive| {
        let mut o = JsonObj::new();
        o.insert("frontend", Json::str(name));
        o.insert("conns", Json::num(conns as f64));
        o.insert("server_threads", Json::num(threads as f64));
        o.insert("conns_per_thread", Json::num(conns as f64 / threads as f64));
        o.insert("goodput_rps", Json::num(d.goodput_rps));
        o.insert("answered", Json::num(d.answered as f64));
        o.insert("p50_s", Json::num(d.p50_s));
        o.insert("p99_s", Json::num(d.p99_s));
        Json::Obj(o)
    };
    let mut o = JsonObj::new();
    o.insert("bench", Json::str("frontend"));
    o.insert("workers", Json::num(workers as f64));
    o.insert(
        "cases",
        Json::Arr(vec![
            case("threads", threads_threads, &threaded),
            case("reactor", reactor_threads, &reactor),
        ]),
    );
    o.insert("ratio_conns_per_thread", Json::num(ratio_conns));
    o.insert("goodput_ratio", Json::num(ratio_goodput));
    o.insert("reactor_10x_at_95pct_goodput", Json::Bool(verdict));
    o.insert("micro", micro.to_json());
    emit_json("frontend", Json::Obj(o)).expect("emit json");
}
