//! Frontend bench: the event-driven reactor vs the thread-per-connection
//! path (the ISSUE 9 acceptance bar), plus the PR 10 sharding and
//! vectored-I/O axes.
//!
//! Drives hundreds of concurrent closed-loop connections against the
//! same synthetic pool behind each frontend and compares:
//!
//! * **connections per server thread** -- the threaded frontend spends
//!   one OS thread per client (+1 acceptor); the reactor spends event
//!   loops + a worker pool sized to cores regardless of client count.
//!   The bar: the reactor sustains >= 10x the connections per server
//!   thread;
//! * **goodput** -- answered roundtrips per second; the reactor must
//!   hold >= 95% of the threaded frontend's goodput at the same
//!   connection count;
//! * **p50/p99 roundtrip latency** for the record.
//!
//! A second, pipelined group (unix only) saturates the reactor itself
//! with batched lines over a near-free backend and sweeps the shard
//! count (1/2/4):
//!
//! * **writes per reply** -- write syscalls issued per reply drained
//!   ([`abc_serve::server::conn::wire_stats`] deltas); one-write-per-
//!   reply is the non-vectored baseline, so `writev` must land >= 30%
//!   fewer (<= 0.7);
//! * **shard scaling** -- 4 shards must reach >= 2x the goodput of 1
//!   shard at saturation.
//!
//! A micro group times the wire-decode paths themselves: the lazy
//! `scan_request_line` (no JSON tree) vs the eager `parse_request_line`
//! on a representative infer line.
//!
//! Run: `cargo bench --bench bench_frontend`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use abc_serve::benchkit::{black_box, emit_json, Bench};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::metrics::Metrics;
use abc_serve::server::proto::{parse_request_line, scan_request_line};
use abc_serve::server::{serve_with, Client, Frontend, InferReply};
use abc_serve::trafficgen::SyntheticClassifier;
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::stats::Samples;
use abc_serve::util::table::Table;

const DIM: usize = 8;
const PER_ROW: Duration = Duration::from_micros(50);
const RUN: Duration = Duration::from_secs(2);

fn pool() -> Arc<ReplicaPool> {
    Arc::new(ReplicaPool::spawn(
        Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW)),
        PoolConfig {
            replicas: 1,
            // admission must hold every connection's in-flight line:
            // the bench measures the frontends, not the shed policy
            max_queue: 1024,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(1),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
    ))
}

struct Drive {
    goodput_rps: f64,
    p50_s: f64,
    p99_s: f64,
    answered: u64,
}

/// Closed-loop load: `conns` client threads, each ping-ponging infer
/// roundtrips until the deadline.  Returns goodput over the measured
/// window and the merged latency quantiles.
fn drive(frontend: Frontend, port: u16, conns: usize) -> Drive {
    let server_pool = pool();
    let server = std::thread::spawn(move || serve_with(server_pool, port, frontend));
    std::thread::sleep(Duration::from_millis(300));

    let answered = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let deadline = t0 + RUN;
    let clients: Vec<_> = (0..conns)
        .map(|c| {
            let answered = Arc::clone(&answered);
            std::thread::spawn(move || {
                let mut client = Client::connect(port).expect("connect");
                let feats: Vec<f32> =
                    (0..DIM).map(|i| (c + i) as f32 * 0.01).collect();
                let mut lat = Vec::new();
                let mut id = (c as u64) << 32;
                while Instant::now() < deadline {
                    let sent = Instant::now();
                    match client.infer_reply(id, &feats) {
                        Ok(InferReply::Verdict(_)) => {
                            lat.push(sent.elapsed().as_secs_f64());
                            answered.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(InferReply::Overloaded { .. }) => {}
                        Err(_) => break,
                    }
                    id += 1;
                }
                lat
            })
        })
        .collect();
    let mut samples = Samples::new();
    for c in clients {
        samples.extend(&c.join().expect("client thread"));
    }
    let elapsed = t0.elapsed().as_secs_f64();

    let mut stopper = Client::connect(port).expect("connect for shutdown");
    stopper.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("serve");

    let answered = answered.load(Ordering::Relaxed);
    Drive {
        goodput_rps: answered as f64 / elapsed,
        p50_s: samples.p50(),
        p99_s: samples.p99(),
        answered,
    }
}

/// A near-free backend so the pipelined group saturates the reactor
/// (framing, dispatch, writev) rather than inference.
#[cfg(unix)]
fn fast_pool() -> Arc<ReplicaPool> {
    Arc::new(ReplicaPool::spawn(
        Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, Duration::ZERO)),
        PoolConfig {
            replicas: 2,
            max_queue: 4096,
            batcher: BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(1),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
    ))
}

#[cfg(unix)]
struct PipeDrive {
    goodput_rps: f64,
    writes_per_reply: f64,
    answered: u64,
}

/// Pipelined load against a sharded reactor: `conns` client threads,
/// each writing `batch` infer lines in ONE write then reading `batch`
/// replies, until the deadline.  The batch keeps several replies
/// queued per connection so the reply path can exercise `writev`;
/// writes-per-reply comes from `wire_stats` deltas over the window.
#[cfg(unix)]
fn drive_pipelined(shards: usize, port: u16, conns: usize, batch: usize) -> PipeDrive {
    use abc_serve::server::conn::wire_stats;
    use abc_serve::server::reactor::{serve_reactor_with, ReactorConfig};
    use std::io::{BufRead, BufReader, Write};

    let server_pool = fast_pool();
    let server = std::thread::spawn(move || {
        serve_reactor_with(
            server_pool,
            port,
            ReactorConfig { shards, ..ReactorConfig::default() },
        )
    });
    std::thread::sleep(Duration::from_millis(300));

    let (w0, r0) = wire_stats();
    let t0 = Instant::now();
    let deadline = t0 + RUN;
    let clients: Vec<_> = (0..conns)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = std::net::TcpStream::connect(("127.0.0.1", port))
                    .expect("connect");
                stream.set_nodelay(true).expect("nodelay");
                let mut writer = stream.try_clone().expect("clone stream");
                let mut reader = BufReader::new(stream);
                let feats = (0..DIM)
                    .map(|d| format!("{:.2}", (c + d) as f32 * 0.01))
                    .collect::<Vec<_>>()
                    .join(",");
                let mut block = String::new();
                for i in 0..batch {
                    block.push_str(&format!(
                        "{{\"id\":{},\"features\":[{feats}]}}\n",
                        c * batch + i
                    ));
                }
                let mut line = String::new();
                let mut ok = 0u64;
                while Instant::now() < deadline {
                    if writer.write_all(block.as_bytes()).is_err() {
                        break;
                    }
                    for _ in 0..batch {
                        line.clear();
                        if reader.read_line(&mut line).unwrap_or(0) == 0 {
                            return ok;
                        }
                        if line.contains("\"prediction\"") {
                            ok += 1;
                        }
                    }
                }
                ok
            })
        })
        .collect();
    let mut answered = 0u64;
    for c in clients {
        answered += c.join().expect("client thread");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let (w1, r1) = wire_stats();

    let mut stopper = Client::connect(port).expect("connect for shutdown");
    stopper.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("serve");

    PipeDrive {
        goodput_rps: answered as f64 / elapsed,
        writes_per_reply: (w1 - w0) as f64 / (r1 - r0).max(1) as f64,
        answered,
    }
}

fn main() {
    // wire-decode micro: what one line costs on each path
    let line = r#"{"id":123,"features":[0.125,-0.5,0.25,1.0,0.75,-0.125,0.0625,2.0],"class":"premium"}"#;
    const OPS: usize = 1000;
    let mut micro = Bench::new("frontend: wire decode (x1000 per iter)");
    micro.run("scan_request_line (lazy)", || {
        for _ in 0..OPS {
            black_box(scan_request_line(black_box(line)).is_ok());
        }
    });
    micro.run("parse_request_line (tree)", || {
        for _ in 0..OPS {
            black_box(parse_request_line(black_box(line)).is_ok());
        }
    });
    micro.report();

    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let conns = (12 * (workers + 1)).clamp(120, 480);
    // server-side thread budget at `conns` connections
    let threads_threads = conns + 1; // one handler per client + acceptor
    let reactor_threads = workers + 1; // worker pool + the event loop
    println!(
        "closed loop: {conns} connections x {:.0?} against 1 replica \
         ({workers} reactor workers)\n",
        RUN
    );

    let threaded = drive(Frontend::Threads, 8117, conns);
    let reactor = drive(Frontend::Reactor, 8118, conns);

    let mut table = Table::new(
        "frontend comparison",
        &["frontend", "conns", "srv threads", "conns/thread", "goodput r/s", "p50 ms", "p99 ms"],
    );
    for (name, threads, d) in [
        ("threads", threads_threads, &threaded),
        ("reactor", reactor_threads, &reactor),
    ] {
        table.row(vec![
            name.to_string(),
            format!("{conns}"),
            format!("{threads}"),
            format!("{:.1}", conns as f64 / threads as f64),
            format!("{:.0}", d.goodput_rps),
            format!("{:.2}", d.p50_s * 1e3),
            format!("{:.2}", d.p99_s * 1e3),
        ]);
    }
    println!("{}", table.render());

    let ratio_conns = (conns as f64 / reactor_threads as f64)
        / (conns as f64 / threads_threads as f64);
    let ratio_goodput = reactor.goodput_rps / threaded.goodput_rps.max(1e-9);
    println!(
        "reactor vs threads: {ratio_conns:.1}x connections per server \
         thread at {:.1}% goodput",
        100.0 * ratio_goodput
    );
    let verdict = ratio_conns >= 10.0 && ratio_goodput >= 0.95;
    println!(
        "verdict: reactor >= 10x connections/thread at >= 95% goodput: {}",
        if verdict { "YES" } else { "NO" },
    );

    let case = |name: &str, threads: usize, d: &Drive| {
        let mut o = JsonObj::new();
        o.insert("frontend", Json::str(name));
        o.insert("conns", Json::num(conns as f64));
        o.insert("server_threads", Json::num(threads as f64));
        o.insert("conns_per_thread", Json::num(conns as f64 / threads as f64));
        o.insert("goodput_rps", Json::num(d.goodput_rps));
        o.insert("answered", Json::num(d.answered as f64));
        o.insert("p50_s", Json::num(d.p50_s));
        o.insert("p99_s", Json::num(d.p99_s));
        Json::Obj(o)
    };
    let mut o = JsonObj::new();
    o.insert("bench", Json::str("frontend"));
    o.insert("workers", Json::num(workers as f64));
    o.insert(
        "cases",
        Json::Arr(vec![
            case("threads", threads_threads, &threaded),
            case("reactor", reactor_threads, &reactor),
        ]),
    );
    o.insert("ratio_conns_per_thread", Json::num(ratio_conns));
    o.insert("goodput_ratio", Json::num(ratio_goodput));
    o.insert("reactor_10x_at_95pct_goodput", Json::Bool(verdict));

    #[cfg(unix)]
    {
        let pipe_conns = (4 * workers).clamp(16, 64);
        let batch = 16;
        println!(
            "\npipelined: {pipe_conns} connections x {batch} lines/write x \
             {:.0?} against a near-free backend, shards 1/2/4\n",
            RUN
        );
        let shards_axis = [1usize, 2, 4];
        let ports = [8119u16, 8120, 8121];
        let drives: Vec<PipeDrive> = shards_axis
            .iter()
            .zip(ports)
            .map(|(&s, p)| drive_pipelined(s, p, pipe_conns, batch))
            .collect();

        let mut table = Table::new(
            "sharded reactor (pipelined load)",
            &["shards", "conns", "goodput r/s", "answered", "writes/reply"],
        );
        for (s, d) in shards_axis.iter().zip(&drives) {
            table.row(vec![
                format!("{s}"),
                format!("{pipe_conns}"),
                format!("{:.0}", d.goodput_rps),
                format!("{}", d.answered),
                format!("{:.3}", d.writes_per_reply),
            ]);
        }
        println!("{}", table.render());

        // one-write-per-reply is the non-vectored baseline: writev must
        // batch the queue into >= 30% fewer write syscalls per reply
        let wpr = drives[0].writes_per_reply;
        let writev_verdict = wpr <= 0.7;
        println!(
            "verdict: writev >= 30% fewer write syscalls per reply \
             ({wpr:.3} <= 0.7): {}",
            if writev_verdict { "YES" } else { "NO" },
        );
        let scale = drives[2].goodput_rps / drives[0].goodput_rps.max(1e-9);
        let scale_verdict = scale >= 2.0;
        println!(
            "verdict: 4 shards >= 2x goodput of 1 shard at saturation \
             ({scale:.2}x): {}",
            if scale_verdict { "YES" } else { "NO" },
        );

        let mut po = JsonObj::new();
        po.insert("conns", Json::num(pipe_conns as f64));
        po.insert("batch", Json::num(batch as f64));
        let cases = shards_axis
            .iter()
            .zip(&drives)
            .map(|(&s, d)| {
                let mut c = JsonObj::new();
                c.insert("shards", Json::num(s as f64));
                c.insert("goodput_rps", Json::num(d.goodput_rps));
                c.insert("answered", Json::num(d.answered as f64));
                c.insert("writes_per_reply", Json::num(d.writes_per_reply));
                Json::Obj(c)
            })
            .collect();
        po.insert("cases", Json::Arr(cases));
        po.insert("writes_per_reply_1shard", Json::num(wpr));
        po.insert("writev_30pct_fewer_writes", Json::Bool(writev_verdict));
        po.insert("shard4_vs_1_goodput", Json::num(scale));
        po.insert("shards4_2x_goodput", Json::Bool(scale_verdict));
        o.insert("pipelined", Json::Obj(po));
    }

    o.insert("micro", micro.to_json());
    emit_json("frontend", Json::Obj(o)).expect("emit json");
}
