//! Serving-cost bench: monolithic replicas vs the tiered fleet under
//! on-off load (the §5.2.2 rental-cost claim as a head-to-head).
//!
//! Replays the same on-off trace (bursts at 2x the monolithic pool's
//! saturation) against two layouts of the same cascade:
//!
//! * **monolithic** -- every replica runs the whole cascade, so every
//!   machine is provisioned for the top model (H100);
//! * **tiered** -- one pool per cascade level with deferral routed
//!   between pools: cheap GPUs (V100/A6000) serve the cheap tiers that
//!   answer most traffic, ONE H100 serves the deferral tail.
//!
//! The rendered table shows goodput, p99, **$/1k completed** (each
//! pool's `replica_seconds` priced at its own GPU class, `cost::rental`
//! Table 4) and the per-tier replica counts.  The verdict line checks
//! the acceptance bar: tiered goodput within 5% of monolithic at
//! measurably fewer fleet-dollars.
//!
//! Run: `cargo bench --bench bench_tiers`.

use std::sync::Arc;
use std::time::Duration;

use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::cascade::StageClassifier;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::coordinator::router::{TierSpec, TieredFleet, TieredFleetConfig};
use abc_serve::cost::rental::Gpu;
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::trafficgen::{
    LoadGen, LoadReport, StagedSynthetic, SyntheticClassifier, Trace,
};
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::table::{fnum, Table};

const DIM: usize = 8;
const LEVELS: usize = 3;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 32;
const PER_ROW: Duration = Duration::from_millis(2); // ~500 rows/s/replica
const WEIGHTS: [f64; 3] = [0.15, 0.25, 0.60];
const MONO_REPLICAS: usize = 4;
const N_REQUESTS: usize = 6000;
const WORKERS: usize = 192;

fn inner() -> SyntheticClassifier {
    SyntheticClassifier::new(DIM, LEVELS, Duration::ZERO, PER_ROW)
}

fn batcher() -> BatcherConfig {
    BatcherConfig { max_batch: MAX_BATCH, max_wait: Duration::from_millis(1) }
}

fn onoff_trace() -> Arc<Trace> {
    let rate = 2.0 * MONO_REPLICAS as f64 * inner().capacity_rps(MAX_BATCH);
    Arc::new(Trace::synth(
        Arrival::OnOff { rate, on_s: 0.4, off_s: 0.5 },
        N_REQUESTS,
        DIM,
        53,
    ))
}

/// (report, fleet dollars, per-tier replica description).
fn run_monolithic(trace: Arc<Trace>) -> (LoadReport, f64, String) {
    let pool = Arc::new(ReplicaPool::spawn(
        Arc::new(inner()),
        PoolConfig {
            replicas: MONO_REPLICAS,
            max_queue: MAX_QUEUE,
            batcher: batcher(),
            ..PoolConfig::default() // gpu: H100 -- the top model rides along
        },
        Metrics::new(),
    ));
    let report = LoadGen { workers: WORKERS, class_mix: None }
        .run(&pool, trace, &Metrics::new())
        .expect("monolithic run");
    let dollars = pool.dollars();
    let desc = format!("{}x{}", MONO_REPLICAS, pool.gpu().name());
    (report, dollars, desc)
}

fn run_tiered(trace: Arc<Trace>) -> (LoadReport, f64, String) {
    let stage = Arc::new(StagedSynthetic::new(inner(), WEIGHTS.to_vec()));
    let fleet = Arc::new(
        TieredFleet::spawn(
            stage as Arc<dyn StageClassifier>,
            TieredFleetConfig {
                tiers: vec![
                    TierSpec::fixed(Gpu::V100, 2, MAX_QUEUE),
                    TierSpec::fixed(Gpu::A6000, 2, MAX_QUEUE),
                    TierSpec::fixed(Gpu::H100, 1, MAX_QUEUE),
                ],
                batcher: batcher(),
                class_weights: None,
            },
            Metrics::new(),
        )
        .expect("fleet spawn"),
    );
    let report = LoadGen { workers: WORKERS, class_mix: None }
        .run(&fleet, trace, &Metrics::new())
        .expect("tiered run");
    let dollars = fleet.dollars();
    let desc = fleet
        .tiers()
        .iter()
        .map(|t| format!("{}x{}", t.pool().n_replicas(), t.gpu().name()))
        .collect::<Vec<_>>()
        .join("+");
    (report, dollars, desc)
}

fn main() {
    let trace = onoff_trace();
    let mono_cap = MONO_REPLICAS as f64 * inner().capacity_rps(MAX_BATCH);
    println!(
        "on-off trace: {} requests, bursts at {:.0} rps (2x the monolithic \
         pool's {:.0} rps saturation), cascade weights {:?}",
        trace.len(),
        2.0 * mono_cap,
        mono_cap,
        WEIGHTS,
    );

    let (mono, mono_dollars, mono_desc) = run_monolithic(Arc::clone(&trace));
    let (tiered, tiered_dollars, tiered_desc) = run_tiered(Arc::clone(&trace));

    let mut table = Table::new(
        "monolithic vs tiered fleet under on-off load (2x saturation)",
        &["config", "fleet", "done", "shed", "goodput rps", "p99",
          "$ total", "$/1k done"],
    );
    let mut row = |name: &str, desc: &str, r: &LoadReport, d: f64| {
        table.row(vec![
            name.to_string(),
            desc.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            format!("{:.0}", r.goodput_rps),
            abc_serve::benchkit::fmt_time(r.p99_s),
            fnum(d, 6),
            fnum(d * 1000.0 / (r.completed.max(1) as f64), 6),
        ]);
    };
    row("monolithic", &mono_desc, &mono, mono_dollars);
    row("tiered", &tiered_desc, &tiered, tiered_dollars);
    println!("{}", table.render());

    let goodput_ratio = tiered.completed as f64 / mono.completed.max(1) as f64;
    let dollar_ratio = tiered_dollars / mono_dollars.max(1e-12);
    println!(
        "tiered goodput = {:.1}% of monolithic at {:.1}% of its fleet-dollars.",
        100.0 * goodput_ratio,
        100.0 * dollar_ratio,
    );
    println!(
        "verdict: goodput within 5% of monolithic: {};  fewer fleet-dollars: {}",
        if goodput_ratio >= 0.95 { "YES" } else { "NO" },
        if dollar_ratio < 0.9 { "YES" } else { "NO" },
    );

    let case = |name: &str, desc: &str, r: &LoadReport, d: f64| {
        let mut o = JsonObj::new();
        o.insert("config", Json::str(name));
        o.insert("fleet", Json::str(desc));
        o.insert("dollars", Json::num(d));
        o.insert(
            "dollars_per_1k",
            Json::num(d * 1000.0 / (r.completed.max(1) as f64)),
        );
        o.insert("report", r.to_json());
        Json::Obj(o)
    };
    let mut o = JsonObj::new();
    o.insert("bench", Json::str("tiers"));
    o.insert(
        "cases",
        Json::Arr(vec![
            case("monolithic", &mono_desc, &mono, mono_dollars),
            case("tiered", &tiered_desc, &tiered, tiered_dollars),
        ]),
    );
    o.insert("goodput_ratio", Json::num(goodput_ratio));
    o.insert("dollar_ratio", Json::num(dollar_ratio));
    o.insert("goodput_within_5pct", Json::Bool(goodput_ratio >= 0.95));
    o.insert("fewer_fleet_dollars", Json::Bool(dollar_ratio < 0.9));
    abc_serve::benchkit::emit_json("tiers", Json::Obj(o)).expect("emit json");
}
