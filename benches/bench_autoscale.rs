//! Serving-scale bench: fixed-N replica fleets vs the elastic
//! autoscaler under on-off load.
//!
//! Replays the same on-off trace (bursts at ~60% of the max fleet's
//! capacity, idle gaps between them) against three deployments of the
//! same classifier:
//!
//! * **fixed max** -- `MAX_REPLICAS` pinned for the whole run: absorbs
//!   every burst but bills for the idle gaps too;
//! * **fixed min** -- one replica pinned: cheap, but sheds most of
//!   every burst;
//! * **elastic** -- the autoscaler growing the fleet into bursts and
//!   draining it back to the floor between them.
//!
//! The rendered table shows goodput, sheds, p99 and **replica-seconds**
//! (the simulated rental bill; multiply by $/replica-hour for dollars,
//! e.g. the paper's Table 4 prices in `cost::rental`).  The verdict
//! line checks the acceptance bar: elastic goodput within 5% of fixed
//! max at measurably fewer replica-seconds.
//!
//! Run: `cargo bench --bench bench_autoscale`.

use std::sync::Arc;
use std::time::Duration;

use abc_serve::control::{
    ControlConfig, ControlLoop, ControlTarget, ControllerConfig, ScaleConfig,
};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::planner::{Gear, GearHandle, GearPlan};
use abc_serve::trafficgen::{LoadGen, LoadReport, SyntheticClassifier, Trace};
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::table::{fnum, Table};

const DIM: usize = 8;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 64;
const PER_ROW: Duration = Duration::from_millis(2); // ~500 rows/s/replica
const MAX_REPLICAS: usize = 4;
const N_REQUESTS: usize = 1600;

fn classifier() -> Arc<SyntheticClassifier> {
    Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW))
}

fn per_replica_rps() -> f64 {
    classifier().capacity_rps(MAX_BATCH)
}

fn one_gear_plan() -> GearPlan {
    GearPlan::new(vec![Gear {
        id: 0,
        k: 3,
        epsilon: 0.03,
        theta: 0.6,
        mid: vec![],
        max_batch: MAX_BATCH,
        replicas: 1,
        tier_fleet: vec![],
        dollar_per_req: 0.0,
        accuracy: 0.95,
        relative_cost: 1.0,
        sustainable_rps: per_replica_rps(),
    }])
    .unwrap()
}

fn pool_cfg(replicas: usize) -> PoolConfig {
    PoolConfig {
        replicas,
        max_queue: MAX_QUEUE,
        batcher: BatcherConfig {
            max_batch: MAX_BATCH,
            max_wait: Duration::from_millis(1),
        },
        ..PoolConfig::default()
    }
}

fn onoff_trace() -> Arc<Trace> {
    let rate = 0.6 * MAX_REPLICAS as f64 * per_replica_rps();
    Arc::new(Trace::synth(
        Arrival::OnOff { rate, on_s: 0.4, off_s: 0.6 },
        N_REQUESTS,
        DIM,
        29,
    ))
}

/// (report, replica-seconds) for a pinned fleet of `n` replicas.
fn run_fixed(n: usize, trace: Arc<Trace>) -> (LoadReport, f64) {
    let pool = Arc::new(ReplicaPool::spawn(classifier(), pool_cfg(n), Metrics::new()));
    let report = LoadGen { workers: 64, class_mix: None }
        .run(&pool, trace, &Metrics::new())
        .expect("fixed run");
    let rs = pool.replica_seconds();
    (report, rs)
}

/// (report, replica-seconds, scale-ups, scale-downs) for the elastic
/// fleet.
fn run_elastic(trace: Arc<Trace>) -> (LoadReport, f64, u64, u64) {
    let plan = one_gear_plan();
    let handle = GearHandle::new(plan.top().config());
    let metrics = Metrics::new();
    let pool = Arc::new(ReplicaPool::spawn_geared(
        classifier(),
        pool_cfg(1),
        Arc::clone(&metrics),
        Arc::clone(&handle),
    ));
    let _autoscaler = ControlLoop::spawn(
        Arc::clone(&pool) as Arc<dyn ControlTarget>,
        ControlConfig::autoscaled(
            plan,
            ControllerConfig {
                sample_every: Duration::from_millis(10),
                dwell: Duration::from_millis(80),
                ..ControllerConfig::default()
            },
            ScaleConfig {
                min_replicas: 1,
                max_replicas: MAX_REPLICAS,
                warmup: Duration::ZERO,
                ..ScaleConfig::default()
            },
            0.0,
        ),
    );
    let report = LoadGen { workers: 64, class_mix: None }
        .run(&pool, trace, &Metrics::new())
        .expect("elastic run");
    let rs = pool.replica_seconds();
    (
        report,
        rs,
        metrics.counter("scale_up_total").get(),
        metrics.counter("scale_down_total").get(),
    )
}

fn main() {
    let trace = onoff_trace();
    let burst = 0.6 * MAX_REPLICAS as f64 * per_replica_rps();
    println!(
        "on-off trace: {} requests, bursts at {:.0} rps (60% of the {}-replica \
         fleet's {:.0} rps), {:.0} rps/replica",
        trace.len(),
        burst,
        MAX_REPLICAS,
        MAX_REPLICAS as f64 * per_replica_rps(),
        per_replica_rps(),
    );

    let (fixed_max, max_rs) = run_fixed(MAX_REPLICAS, Arc::clone(&trace));
    let (fixed_min, min_rs) = run_fixed(1, Arc::clone(&trace));
    let (elastic, elastic_rs, ups, downs) = run_elastic(Arc::clone(&trace));

    let mut table = Table::new(
        "fixed-N vs elastic under on-off load",
        &["config", "done", "shed", "err", "goodput rps", "p99", "replica-s",
          "rep-s/1k done"],
    );
    let mut row = |name: &str, r: &LoadReport, rs: f64| {
        table.row(vec![
            name.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.errors.to_string(),
            format!("{:.0}", r.goodput_rps),
            abc_serve::benchkit::fmt_time(r.p99_s),
            fnum(rs, 2),
            fnum(rs * 1000.0 / (r.completed.max(1) as f64), 2),
        ]);
    };
    row(&format!("fixed max ({MAX_REPLICAS} replicas)"), &fixed_max, max_rs);
    row("fixed min (1 replica)", &fixed_min, min_rs);
    row(
        &format!("elastic (1..={MAX_REPLICAS}, autoscaler)"),
        &elastic,
        elastic_rs,
    );
    println!("{}", table.render());

    let goodput_ratio = elastic.completed as f64 / fixed_max.completed.max(1) as f64;
    let rent_ratio = elastic_rs / max_rs.max(1e-9);
    println!(
        "autoscaler scaled up {ups}x / down {downs}x.  elastic goodput = \
         {:.1}% of fixed max at {:.1}% of its replica-seconds.",
        100.0 * goodput_ratio,
        100.0 * rent_ratio,
    );
    println!(
        "verdict: goodput within 5% of fixed max: {};  fewer replica-seconds: {}",
        if goodput_ratio >= 0.95 { "YES" } else { "NO" },
        if rent_ratio < 0.9 { "YES" } else { "NO" },
    );

    let case = |name: &str, r: &LoadReport, rs: f64| {
        let mut o = JsonObj::new();
        o.insert("config", Json::str(name));
        o.insert("replica_seconds", Json::num(rs));
        o.insert(
            "replica_seconds_per_1k",
            Json::num(rs * 1000.0 / (r.completed.max(1) as f64)),
        );
        o.insert("report", r.to_json());
        Json::Obj(o)
    };
    let mut o = JsonObj::new();
    o.insert("bench", Json::str("autoscale"));
    o.insert(
        "cases",
        Json::Arr(vec![
            case("fixed_max", &fixed_max, max_rs),
            case("fixed_min", &fixed_min, min_rs),
            case("elastic", &elastic, elastic_rs),
        ]),
    );
    o.insert("scale_ups", Json::num(ups as f64));
    o.insert("scale_downs", Json::num(downs as f64));
    o.insert("goodput_ratio", Json::num(goodput_ratio));
    o.insert("rent_ratio", Json::num(rent_ratio));
    o.insert("goodput_within_5pct", Json::Bool(goodput_ratio >= 0.95));
    o.insert("fewer_replica_seconds", Json::Bool(rent_ratio < 0.9));
    abc_serve::benchkit::emit_json("autoscale", Json::Obj(o)).expect("emit json");
}
