//! Observability-overhead bench: goodput with tracing off vs sampled
//! vs tracing everything (the ISSUE 6 acceptance bar).
//!
//! Drives the open-loop trafficgen at 2x a two-replica pool's
//! saturation -- so goodput measures *capacity*, not offered load --
//! under three hooks on the same synthetic cascade:
//!
//! * **no-trace** -- `ObsHook::monolithic(None)`: the baseline;
//! * **sample-100** -- 1-in-100 requests traced (`--trace-sample 100`):
//!   must stay within 5% of the baseline's goodput;
//! * **sample-1** -- every request traced: the worst case, reported for
//!   the record (no bar).
//!
//! A micro group times the hot-path primitives themselves (striped
//! counter inc, histogram record, span record, unsampled branch).
//!
//! Run: `cargo bench --bench bench_obs`.

use std::sync::Arc;
use std::time::Duration;

use abc_serve::benchkit::{black_box, emit_json, Bench};
use abc_serve::coordinator::batcher::BatcherConfig;
use abc_serve::coordinator::replica::{PoolConfig, ReplicaPool};
use abc_serve::data::workload::Arrival;
use abc_serve::metrics::Metrics;
use abc_serve::obs::{ObsHook, SpanKind, Tracer};
use abc_serve::trafficgen::{LoadGen, LoadReport, SyntheticClassifier, Trace};
use abc_serve::util::json::{Json, JsonObj};
use abc_serve::util::table::Table;

const DIM: usize = 8;
const MAX_BATCH: usize = 8;
const MAX_QUEUE: usize = 32;
const PER_ROW: Duration = Duration::from_millis(2); // ~500 rows/s/replica
const REPLICAS: usize = 2;
const RUN_S: f64 = 0.6;

fn classifier() -> Arc<SyntheticClassifier> {
    Arc::new(SyntheticClassifier::new(DIM, 3, Duration::ZERO, PER_ROW))
}

fn run_point(tracer: Option<Arc<Tracer>>, seed: u64) -> LoadReport {
    let pool = Arc::new(ReplicaPool::spawn_with_obs(
        classifier(),
        PoolConfig {
            replicas: REPLICAS,
            max_queue: MAX_QUEUE,
            batcher: BatcherConfig {
                max_batch: MAX_BATCH,
                max_wait: Duration::from_millis(1),
            },
            ..PoolConfig::default()
        },
        Metrics::new(),
        None,
        ObsHook::monolithic(tracer),
    ));
    let capacity = REPLICAS as f64 * classifier().capacity_rps(MAX_BATCH);
    let offered = 2.0 * capacity;
    let n = (offered * RUN_S) as usize;
    let trace = Arc::new(Trace::synth(Arrival::Poisson { rate: offered }, n, DIM, seed));
    let workers = (REPLICAS * MAX_QUEUE * 2).clamp(32, 512);
    LoadGen { workers, class_mix: None }
        .run(&pool, trace, &Metrics::new())
        .expect("load run")
}

fn main() {
    // hot-path primitives first: what one operation costs
    let metrics = Metrics::new();
    let counter = metrics.counter("bench_ops");
    let hist = metrics.histogram("bench_lat_s");
    let tracer = Tracer::new(1);
    let unsampled = Tracer::new(1_000_000);
    const OPS: usize = 1000;
    let mut micro = Bench::new("obs: hot-path primitives (x1000 per iter)");
    micro.run("counter inc", || {
        for _ in 0..OPS {
            counter.inc();
        }
    });
    micro.run("histogram record", || {
        for _ in 0..OPS {
            hist.record(0.0015);
        }
    });
    micro.run("span record (sampled)", || {
        for i in 0..OPS as u64 {
            tracer.record(i, SpanKind::Infer, 0, 0.001);
        }
    });
    micro.run("sampling branch (unsampled)", || {
        for i in 0..OPS as u64 {
            black_box(unsampled.sampled(i));
        }
    });
    micro.report();

    let capacity = REPLICAS as f64 * classifier().capacity_rps(MAX_BATCH);
    println!(
        "pool: {REPLICAS} replicas x {:.0} rows/s, offered at 2x saturation \
         so goodput below measures capacity under each hook\n",
        capacity / REPLICAS as f64,
    );
    let none = run_point(None, 11);
    let sampled = run_point(Some(Tracer::new(100)), 11);
    let all = run_point(Some(Tracer::new(1)), 11);

    let mut table =
        Table::new("goodput under tracing hooks (2x saturation)", LoadReport::header());
    table.row(none.row_cells());
    table.row(sampled.row_cells());
    table.row(all.row_cells());
    println!("{}", table.render());

    let ratio_100 = sampled.goodput_rps / none.goodput_rps.max(1e-9);
    let ratio_1 = all.goodput_rps / none.goodput_rps.max(1e-9);
    println!(
        "goodput vs no-trace: sample-100 = {:.1}%, sample-1 = {:.1}%",
        100.0 * ratio_100,
        100.0 * ratio_1,
    );
    println!(
        "verdict: --trace-sample 100 within 5% of no-trace goodput: {}",
        if ratio_100 >= 0.95 { "YES" } else { "NO" },
    );

    let case = |name: &str, sample_every: u64, r: &LoadReport| {
        let mut o = JsonObj::new();
        o.insert("config", Json::str(name));
        o.insert("sample_every", Json::num(sample_every as f64));
        o.insert("report", r.to_json());
        Json::Obj(o)
    };
    let mut o = JsonObj::new();
    o.insert("bench", Json::str("obs"));
    o.insert(
        "cases",
        Json::Arr(vec![
            case("no_trace", 0, &none),
            case("sample_100", 100, &sampled),
            case("sample_1", 1, &all),
        ]),
    );
    o.insert("goodput_ratio_sample_100", Json::num(ratio_100));
    o.insert("goodput_ratio_sample_1", Json::num(ratio_1));
    o.insert("sample_100_within_5pct", Json::Bool(ratio_100 >= 0.95));
    o.insert("micro", micro.to_json());
    emit_json("obs", Json::Obj(o)).expect("emit json");
}
