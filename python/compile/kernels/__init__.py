# L1: Pallas kernels for the paper's compute hot-spot (ensemble forward +
# agreement reduce).  ref.py holds the pure-jnp oracles.
from .agreement import agreement
from .ensemble_linear import ensemble_linear, ensemble_linear_member

__all__ = ["agreement", "ensemble_linear", "ensemble_linear_member"]
