"""L1 Pallas kernel: fused k-member ensemble linear layer.

The compute hot-spot of ABC is evaluating an ensemble of k models on the
*same* batch.  Instead of looping over members in Python (k dispatches,
k HBM round-trips for the activations), the member axis is a **grid
dimension**: the kernel runs a ``(k, B/bB, O/bO)`` grid where each program
holds one ``(bB, I)`` activation block and one ``(I, bO)`` weight block in
VMEM and issues a single MXU matmul.  This is the TPU-shaped analogue of
the paper's parallel ensemble execution (rho -> 1, §4.1): members become
independent grid programs a real TPU pipelines across cores, and the
BlockSpec expresses the HBM<->VMEM schedule (DESIGN.md §2).

Two variants:

* ``ensemble_linear``        -- shared input  x: (B, I)   (first layer)
* ``ensemble_linear_member`` -- per-member    x: (k, B, I) (deeper layers)

Both return ``(k, B, O)``.  ``interpret=True`` always: the CPU PJRT plugin
cannot execute Mosaic custom-calls; interpret mode lowers the identical
dataflow to plain HLO (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM-friendly tile sizes: with bB = 128, bO = 512 and I <= 512,
# (bB*I + I*bO + bB*bO) * 4B  <=  (128*512 + 512*512 + 128*512) * 4  ~= 1.6 MiB
# per program, far under the ~16 MiB VMEM budget; the MXU sees dense
# (128, I) x (I, 512) f32 matmuls.  bO = 512 (up from 128) was a perf-pass
# change: it quarters the grid steps of the widest tiers, which under the
# interpret-mode lowering means 4x fewer while-loop iterations on the CPU
# PJRT path too (EXPERIMENTS.md SS Perf L1).
DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_O = 512


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _pad_axis(a, axis: int, mult: int):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _shared_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    # x_ref: (bB, I); w_ref: (1, I, bO); b_ref: (1, bO); o_ref: (1, bB, bO)
    x = x_ref[...]
    w = w_ref[0]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b_ref[0][None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[0] = y.astype(o_ref.dtype)


def _member_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    # x_ref: (1, bB, I); w_ref: (1, I, bO); b_ref: (1, bO); o_ref: (1, bB, bO)
    x = x_ref[0]
    w = w_ref[0]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b_ref[0][None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[0] = y.astype(o_ref.dtype)


def ensemble_linear(x, w, b, *, activation: str = "none",
                    block_b: int = DEFAULT_BLOCK_B,
                    block_o: int = DEFAULT_BLOCK_O):
    """y[m] = act(x @ w[m] + b[m]) for every ensemble member m.

    x: (B, I) shared input; w: (k, I, O); b: (k, O)  ->  (k, B, O).
    """
    k, i_dim, o_dim = w.shape
    batch = x.shape[0]
    if x.ndim != 2 or x.shape[1] != i_dim or b.shape != (k, o_dim):
        raise ValueError(
            f"shape mismatch x={x.shape} w={w.shape} b={b.shape}")
    bB = min(block_b, batch)
    bO = min(block_o, o_dim)
    xp = _pad_axis(x, 0, bB)
    wp = _pad_axis(w, 2, bO)
    bp = _pad_axis(b, 1, bO)
    bp_pad, op_pad = xp.shape[0], wp.shape[2]
    grid = (k, _cdiv(bp_pad, bB), _cdiv(op_pad, bO))
    out = pl.pallas_call(
        functools.partial(_shared_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, i_dim), lambda m, bi, oj: (bi, 0)),
            pl.BlockSpec((1, i_dim, bO), lambda m, bi, oj: (m, 0, oj)),
            pl.BlockSpec((1, bO), lambda m, bi, oj: (m, oj)),
        ],
        out_specs=pl.BlockSpec((1, bB, bO), lambda m, bi, oj: (m, bi, oj)),
        out_shape=jax.ShapeDtypeStruct((k, bp_pad, op_pad), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:, :batch, :o_dim]


def ensemble_linear_member(x, w, b, *, activation: str = "none",
                           block_b: int = DEFAULT_BLOCK_B,
                           block_o: int = DEFAULT_BLOCK_O):
    """y[m] = act(x[m] @ w[m] + b[m]): per-member input variant.

    x: (k, B, I); w: (k, I, O); b: (k, O)  ->  (k, B, O).
    """
    k, i_dim, o_dim = w.shape
    if x.ndim != 3 or x.shape[0] != k or x.shape[2] != i_dim:
        raise ValueError(f"shape mismatch x={x.shape} w={w.shape}")
    batch = x.shape[1]
    bB = min(block_b, batch)
    bO = min(block_o, o_dim)
    xp = _pad_axis(x, 1, bB)
    wp = _pad_axis(w, 2, bO)
    bp = _pad_axis(b, 1, bO)
    b_pad, o_pad = xp.shape[1], wp.shape[2]
    grid = (k, _cdiv(b_pad, bB), _cdiv(o_pad, bO))
    out = pl.pallas_call(
        functools.partial(_member_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bB, i_dim), lambda m, bi, oj: (m, bi, 0)),
            pl.BlockSpec((1, i_dim, bO), lambda m, bi, oj: (m, 0, oj)),
            pl.BlockSpec((1, bO), lambda m, bi, oj: (m, oj)),
        ],
        out_specs=pl.BlockSpec((1, bB, bO), lambda m, bi, oj: (m, bi, oj)),
        out_shape=jax.ShapeDtypeStruct((k, b_pad, o_pad), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:, :batch, :o_dim]
