"""L1 Pallas kernel: the agreement-based deferral rule (paper Eq. 3/4).

Given the stacked per-member logits ``(k, B, C)`` of a tier's ensemble,
one reduction pass over the member axis computes everything the L3
coordinator needs to apply the deferral rule:

* ``majority``  -- the ensemble's (plurality-vote) prediction, i32[B];
* ``vote_frac`` -- vote(x; H^k): fraction of members voting for the
  majority label (Eq. 3's score), f32[B];
* ``mean_score``-- s(x; H^k): mean softmax probability the members assign
  to the majority label (Eq. 4's score), f32[B].

Evaluating the rule *inside* the artifact means the request path ships a
scalar per sample back to the coordinator instead of k*C logits -- this is
what makes the deferral rule "significantly cheaper to evaluate" (§3.1)
in the edge-to-cloud placement, where the reduce runs on-device.

Grid: one program per batch block; each program holds a ``(k, bB, C)``
logits block in VMEM (k <= 8, C <= 128 here: <= 0.5 MiB).  Ties are broken
toward the smaller class index (argmax semantics), matching ref.py and the
Rust-side re-implementation (coordinator/agreement.rs).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _agreement_kernel(logits_ref, maj_ref, frac_ref, score_ref):
    lg = logits_ref[...].astype(jnp.float32)        # (k, bB, C)
    k = lg.shape[0]
    c = lg.shape[2]
    preds = jnp.argmax(lg, axis=-1)                 # (k, bB)
    onehot = jax.nn.one_hot(preds, c, dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=0)                # (bB, C)
    maj = jnp.argmax(counts, axis=-1)               # (bB,)
    frac = jnp.max(counts, axis=-1) / float(k)
    probs = jax.nn.softmax(lg, axis=-1)             # (k, bB, C)
    maj1h = jax.nn.one_hot(maj, c, dtype=jnp.float32)
    score = jnp.mean(jnp.sum(probs * maj1h[None, :, :], axis=-1), axis=0)
    maj_ref[...] = maj.astype(jnp.int32)
    frac_ref[...] = frac
    score_ref[...] = score


def agreement(logits, *, block_b: int = DEFAULT_BLOCK_B):
    """Reduce ensemble logits to (majority, vote_frac, mean_score).

    logits: (k, B, C) -> (i32[B], f32[B], f32[B]).
    """
    if logits.ndim != 3:
        raise ValueError(f"expected (k, B, C) logits, got {logits.shape}")
    k, batch, c = logits.shape
    bB = min(block_b, batch)
    pad = (-batch) % bB
    lp = jnp.pad(logits, ((0, 0), (0, pad), (0, 0))) if pad else logits
    b_pad = lp.shape[1]
    grid = (_cdiv(b_pad, bB),)
    maj, frac, score = pl.pallas_call(
        _agreement_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, bB, c), lambda bi: (0, bi, 0))],
        out_specs=[
            pl.BlockSpec((bB,), lambda bi: (bi,)),
            pl.BlockSpec((bB,), lambda bi: (bi,)),
            pl.BlockSpec((bB,), lambda bi: (bi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad,), jnp.int32),
            jax.ShapeDtypeStruct((b_pad,), jnp.float32),
            jax.ShapeDtypeStruct((b_pad,), jnp.float32),
        ],
        interpret=True,
    )(lp)
    return maj[:batch], frac[:batch], score[:batch]
