"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal for the kernel layer: pytest +
hypothesis sweep shapes/dtypes and assert_allclose kernel-vs-ref
(python/tests/test_kernels.py).  Keep these boring and obviously right.
"""

import jax
import jax.numpy as jnp


def ensemble_linear_ref(x, w, b, *, activation: str = "none"):
    """x: (B, I) shared; w: (k, I, O); b: (k, O) -> (k, B, O)."""
    y = jnp.einsum("bi,kio->kbo", x.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)[:, None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def ensemble_linear_member_ref(x, w, b, *, activation: str = "none"):
    """x: (k, B, I) per-member; w: (k, I, O); b: (k, O) -> (k, B, O)."""
    y = jnp.einsum("kbi,kio->kbo", x.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)[:, None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def agreement_ref(logits):
    """logits: (k, B, C) -> (majority i32[B], vote_frac f32[B], mean_score f32[B]).

    Ties break toward the smaller class index (argmax semantics).
    """
    lg = logits.astype(jnp.float32)
    k, _, c = lg.shape
    preds = jnp.argmax(lg, axis=-1)                      # (k, B)
    onehot = jax.nn.one_hot(preds, c, dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=0)                     # (B, C)
    maj = jnp.argmax(counts, axis=-1)                    # (B,)
    frac = jnp.max(counts, axis=-1) / float(k)
    probs = jax.nn.softmax(lg, axis=-1)
    maj1h = jax.nn.one_hot(maj, c, dtype=jnp.float32)
    score = jnp.mean(jnp.sum(probs * maj1h[None], axis=-1), axis=0)
    return maj.astype(jnp.int32), frac, score
