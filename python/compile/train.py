"""Build-time training of the tier model zoo.

The paper pulls pretrained models off HuggingFace (Table 3); we train our
zoo here, once, inside ``make artifacts``.  All k members of a tier are
trained *jointly*: the member axis leads every parameter array, members
get independent inits and independent minibatch orders (bootstrap-style
diversity -- the source of the disagreement signal ABC relies on), and
the whole thing is one jitted update over the stacked params.

Optimiser: hand-rolled Adam (optax is not on the image; ~20 lines).
"""

import functools
from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model
from .suites import SuiteSpec, TierSpec


@dataclass
class TrainResult:
    params: model.Params
    member_val_acc: List[float]      # per-member accuracy on val
    ensemble_val_acc: float          # majority-vote accuracy on val
    ensemble_test_acc: float
    member_test_acc: List[float]


def _adam_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return (
        [(zeros(w), zeros(b)) for (w, b) in params],  # m
        [(zeros(w), zeros(b)) for (w, b) in params],  # v
    )


@functools.partial(jax.jit, static_argnames=("input_slice", "lr", "wd"))
def _update(params, opt_state, step, xb, yb, *, input_slice, lr, wd):
    """One Adam step on the summed member losses.

    xb: (k, B, D) per-member minibatches; yb: (k, B).
    """
    m_state, v_state = opt_state

    def loss_fn(ps):
        total = 0.0
        k = xb.shape[0]
        for mi in range(k):
            pm = [(w[mi:mi + 1], b[mi:mi + 1]) for (w, b) in ps]
            lg = model.ensemble_logits_ref(pm, xb[mi],
                                           input_slice=input_slice)[0]
            logp = jax.nn.log_softmax(lg, axis=-1)
            nll = -jnp.take_along_axis(logp, yb[mi][:, None], axis=1).mean()
            total = total + nll
        return total / k

    loss, grads = jax.value_and_grad(loss_fn)(params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step + 1
    new_params, new_m, new_v = [], [], []
    for (w, b), (gw, gb), (mw, mb), (vw, vb) in zip(
            params, grads, m_state, v_state):
        outs = []
        for p, g, m_, v_ in ((w, gw, mw, vw), (b, gb, mb, vb)):
            g = g + wd * p
            m_ = b1 * m_ + (1 - b1) * g
            v_ = b2 * v_ + (1 - b2) * g * g
            mhat = m_ / (1 - b1 ** t)
            vhat = v_ / (1 - b2 ** t)
            p = p - lr * mhat / (jnp.sqrt(vhat) + eps)
            outs.append((p, m_, v_))
        (w2, mw2, vw2), (b2_, mb2, vb2) = outs
        new_params.append((w2, b2_))
        new_m.append((mw2, mb2))
        new_v.append((vw2, vb2))
    return new_params, (new_m, new_v), loss


def _member_batches(rng: np.random.Generator, n: int, k: int, bs: int):
    """Independent epoch permutations per member, chunked to minibatches."""
    perms = np.stack([rng.permutation(n) for _ in range(k)])  # (k, n)
    n_batches = n // bs
    for bi in range(n_batches):
        yield perms[:, bi * bs:(bi + 1) * bs]  # (k, bs)


def evaluate(params: model.Params, x: np.ndarray, y: np.ndarray,
             *, input_slice: int, batch: int = 2048):
    """(member accuracies, ensemble majority-vote accuracy), pure-jnp path."""
    k = params[0][0].shape[0]
    member_hits = np.zeros(k, dtype=np.int64)
    ens_hits = 0
    fwd = jax.jit(functools.partial(
        model.ensemble_logits_ref, input_slice=input_slice))
    for s in range(0, len(x), batch):
        xb = jnp.asarray(x[s:s + batch])
        yb = y[s:s + batch]
        lg = np.asarray(fwd(params, xb))            # (k, B, C)
        preds = lg.argmax(-1)                       # (k, B)
        member_hits += (preds == yb[None]).sum(1)
        # plurality vote, ties toward smaller class (same as kernels)
        c = lg.shape[-1]
        counts = np.zeros((len(yb), c), dtype=np.int32)
        for mi in range(k):
            np.add.at(counts, (np.arange(len(yb)), preds[mi]), 1)
        maj = counts.argmax(-1)
        ens_hits += int((maj == yb).sum())
    return (member_hits / len(x)).tolist(), ens_hits / len(x)


def train_tier(spec: SuiteSpec, tier: TierSpec,
               train_xy: Tuple[np.ndarray, np.ndarray],
               val_xy: Tuple[np.ndarray, np.ndarray],
               test_xy: Tuple[np.ndarray, np.ndarray],
               *, batch_size: int = 256, lr: float = 2e-3,
               wd: float = 1e-4, verbose: bool = False) -> TrainResult:
    """Train the k-member ensemble of one tier."""
    xtr, ytr = train_xy
    if tier.train_frac < 1.0:
        n_use = int(len(xtr) * tier.train_frac)
        xtr, ytr = xtr[:n_use], ytr[:n_use]
    rng = np.random.default_rng(spec.seed * 31 + tier.tier)
    params = model.init_params(rng, tier.k, tier.input_slice, tier.hidden,
                               spec.classes)
    opt_state = _adam_init(params)
    step = 0
    xtr_j = jnp.asarray(xtr)
    ytr_j = jnp.asarray(ytr.astype(np.int32))
    for _epoch in range(tier.epochs):
        for idx in _member_batches(rng, len(xtr), tier.k, batch_size):
            xb = xtr_j[jnp.asarray(idx)]            # (k, bs, D)
            yb = ytr_j[jnp.asarray(idx)]            # (k, bs)
            params, opt_state, loss = _update(
                params, opt_state, step, xb, yb,
                input_slice=tier.input_slice, lr=lr, wd=wd)
            step += 1
        if verbose:
            print(f"    epoch {_epoch + 1}/{tier.epochs} loss={float(loss):.4f}")
    mv, ev = evaluate(params, *val_xy, input_slice=tier.input_slice)
    mt, et = evaluate(params, *test_xy, input_slice=tier.input_slice)
    return TrainResult(params=params, member_val_acc=mv, ensemble_val_acc=ev,
                       ensemble_test_acc=et, member_test_acc=mt)
