"""L2: the JAX tier model -- an ensemble MLP classifier built on the L1
Pallas kernels.

A *tier* is an ensemble of ``k`` MLPs with identical architecture but
independent initialisation / data order (the paper sources its ensembles
from model zoos; we train ours at build time, see train.py).  The tier
forward pass is what gets AOT-lowered per batch bucket:

    tier_forward(params, x) ->
        (majority i32[B], vote_frac f32[B], mean_score f32[B],
         logits f32[k, B, C])

Weights are *runtime parameters* of the lowered HLO (flattened in layer
order: w0, b0, w1, b1, ...), shipped to the Rust runtime in an .npz
sidecar: HLO text elides large constants ("constant({...})"), so baking
them is not an option (DESIGN.md §2, interchange format).

There is also a ``single_forward`` variant (member 0 only, confidence =
max softmax) used by the single-model and confidence-cascade (WoC)
baselines.
"""

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import agreement, ensemble_linear, ensemble_linear_member
from .kernels.ref import agreement_ref

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]  # [(w (k,I,O), b (k,O)), ...]


def layer_dims(input_slice: int, hidden: Sequence[int], classes: int):
    """[(in, out)] for every layer of the tier MLP."""
    dims = []
    prev = input_slice
    for h in hidden:
        dims.append((prev, h))
        prev = h
    dims.append((prev, classes))
    return dims


def init_params(rng: np.random.Generator, k: int, input_slice: int,
                hidden: Sequence[int], classes: int) -> Params:
    """He-init per member; member axis leads every array."""
    params: Params = []
    for (i, o) in layer_dims(input_slice, hidden, classes):
        scale = np.sqrt(2.0 / i)
        w = rng.standard_normal((k, i, o)).astype(np.float32) * scale
        b = np.zeros((k, o), dtype=np.float32)
        params.append((jnp.asarray(w), jnp.asarray(b)))
    return params


def ensemble_logits(params: Params, x, *, input_slice: int):
    """Forward through the fused L1 kernels. x: (B, D) -> logits (k, B, C)."""
    h = x[:, :input_slice]
    n_layers = len(params)
    # First layer: shared input across members.
    w, b = params[0]
    act = "relu" if n_layers > 1 else "none"
    y = ensemble_linear(h, w, b, activation=act)
    # Deeper layers: per-member activations.
    for li in range(1, n_layers):
        w, b = params[li]
        act = "relu" if li < n_layers - 1 else "none"
        y = ensemble_linear_member(y, w, b, activation=act)
    return y


def ensemble_logits_ref(params: Params, x, *, input_slice: int):
    """Pure-jnp reference of ensemble_logits (no Pallas) for tests/training."""
    h = x[:, :input_slice].astype(jnp.float32)
    n_layers = len(params)
    y = jnp.einsum("bi,kio->kbo", h, params[0][0]) + params[0][1][:, None, :]
    if n_layers > 1:
        y = jnp.maximum(y, 0.0)
    for li in range(1, n_layers):
        w, b = params[li]
        y = jnp.einsum("kbi,kio->kbo", y, w) + b[:, None, :]
        if li < n_layers - 1:
            y = jnp.maximum(y, 0.0)
    return y


def tier_forward(params: Params, x, *, input_slice: int):
    """The full tier artifact: ensemble forward + agreement reduce."""
    logits = ensemble_logits(params, x, input_slice=input_slice)
    maj, frac, score = agreement(logits)
    return maj, frac, score, logits


def tier_forward_ref(params: Params, x, *, input_slice: int):
    logits = ensemble_logits_ref(params, x, input_slice=input_slice)
    maj, frac, score = agreement_ref(logits)
    return maj, frac, score, logits


def single_forward(params: Params, x, *, input_slice: int):
    """Member-0-only forward for the single-model / WoC baselines.

    Returns (pred i32[B], conf f32[B] = max softmax, logits f32[B, C]).
    Implemented with the same kernels at k=1 so the baseline exercises the
    identical compiled path.
    """
    p1 = [(w[:1], b[:1]) for (w, b) in params]
    logits = ensemble_logits(p1, x, input_slice=input_slice)[0]  # (B, C)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    conf = jnp.max(probs, axis=-1)
    return pred, conf, logits


def flops_per_sample(input_slice: int, hidden: Sequence[int],
                     classes: int) -> int:
    """Forward FLOPs of ONE member on one sample (2*I*O per matmul)."""
    return int(sum(2 * i * o for (i, o) in
                   layer_dims(input_slice, hidden, classes)))


def param_count(input_slice: int, hidden: Sequence[int], classes: int) -> int:
    """Parameters of ONE member."""
    return int(sum(i * o + o for (i, o) in
                   layer_dims(input_slice, hidden, classes)))


def params_to_npz_dict(params: Params) -> Dict[str, np.ndarray]:
    """Flatten params for the .npz sidecar, layer order: w0, b0, w1, b1..."""
    out: Dict[str, np.ndarray] = {}
    for i, (w, b) in enumerate(params):
        out[f"w{i}"] = np.asarray(w, dtype=np.float32)
        out[f"b{i}"] = np.asarray(b, dtype=np.float32)
    return out


def npz_param_names(n_layers: int) -> List[str]:
    names = []
    for i in range(n_layers):
        names += [f"w{i}", f"b{i}"]
    return names
