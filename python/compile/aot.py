"""AOT pipeline: data -> zoo training -> HLO text artifacts + manifest.

Runs ONCE at ``make artifacts`` (Python is never on the request path).
For every suite in suites.py it:

  1. generates the ABDS datasets            -> artifacts/data/
  2. trains the k-member ensemble per tier  -> artifacts/weights/*.npz
  3. AOT-lowers, per batch bucket:
       tier_forward   (ensemble + agreement)        [ENSEMBLE_BUCKETS]
       single_forward (member 0 + max-softmax conf) [SINGLE_BUCKETS]
     to HLO *text*                          -> artifacts/hlo/*.hlo.txt
  4. records accuracies / FLOPs / params    -> artifacts/manifest.json

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).  Weights stay
runtime parameters (HLO text elides large constants) and ship in .npz
sidecars the Rust runtime loads with ``Literal::read_npz``.
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train
from .datagen import generate_suite, make_suite_data
from .suites import ENSEMBLE_BUCKETS, SINGLE_BUCKETS, default_suites

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """Lower a jax .lower() result to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flat_weight_specs(params):
    flat = []
    for w, b in params:
        flat += [w, b]
    return [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in flat]


def lower_tier_ensemble(params, *, input_slice: int, batch: int,
                        dim: int) -> str:
    """HLO text for tier_forward at a fixed batch bucket.

    Parameter order: x, w0, b0, w1, b1, ...  (matches npz_param_names).
    """
    n_layers = len(params)

    def fn(x, *flat_w):
        ps = [(flat_w[2 * i], flat_w[2 * i + 1]) for i in range(n_layers)]
        return model.tier_forward(ps, x, input_slice=input_slice)

    specs = [jax.ShapeDtypeStruct((batch, dim), jnp.float32)]
    specs += _flat_weight_specs(params)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_tier_single(params, *, input_slice: int, batch: int,
                      dim: int) -> str:
    """HLO text for single_forward (member 0) at a fixed batch bucket."""
    n_layers = len(params)

    def fn(x, *flat_w):
        ps = [(flat_w[2 * i], flat_w[2 * i + 1]) for i in range(n_layers)]
        return model.single_forward(ps, x, input_slice=input_slice)

    specs = [jax.ShapeDtypeStruct((batch, dim), jnp.float32)]
    specs += _flat_weight_specs(params)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build_suite(spec, out_dir: Path, *, verbose: bool = True) -> dict:
    """Build all artifacts for one suite; returns its manifest entry."""
    t_suite = time.time()
    if verbose:
        print(f"[aot] suite {spec.name} ({spec.paper_dataset})")
    data_rel = generate_suite(spec, out_dir / "data")
    data_entry = {split: f"data/{name}" for split, name in data_rel.items()}

    tr = make_suite_data(spec, "train")
    va = make_suite_data(spec, "val")
    te = make_suite_data(spec, "test")
    trxy, vaxy, texy = (tr[0], tr[1]), (va[0], va[1]), (te[0], te[1])

    (out_dir / "weights").mkdir(parents=True, exist_ok=True)
    (out_dir / "hlo").mkdir(parents=True, exist_ok=True)

    tiers_entry = []
    for tier in spec.tiers:
        t0 = time.time()
        res = train.train_tier(spec, tier, trxy, vaxy, texy)
        params = res.params
        n_layers = len(params)

        wrel = f"weights/{spec.name}_t{tier.tier}.npz"
        np.savez(out_dir / wrel, **model.params_to_npz_dict(params))

        ens_hlo = {}
        for bucket in ENSEMBLE_BUCKETS:
            rel = f"hlo/{spec.name}_t{tier.tier}_ens_b{bucket}.hlo.txt"
            text = lower_tier_ensemble(params, input_slice=tier.input_slice,
                                       batch=bucket, dim=spec.dim)
            (out_dir / rel).write_text(text)
            ens_hlo[str(bucket)] = rel
        single_hlo = {}
        for bucket in SINGLE_BUCKETS:
            rel = f"hlo/{spec.name}_t{tier.tier}_single_b{bucket}.hlo.txt"
            text = lower_tier_single(params, input_slice=tier.input_slice,
                                     batch=bucket, dim=spec.dim)
            (out_dir / rel).write_text(text)
            single_hlo[str(bucket)] = rel

        tiers_entry.append({
            "tier": tier.tier,
            "k": tier.k,
            "hidden": list(tier.hidden),
            "input_slice": tier.input_slice,
            "flops_per_sample_member": model.flops_per_sample(
                tier.input_slice, tier.hidden, spec.classes),
            "params_member": model.param_count(
                tier.input_slice, tier.hidden, spec.classes),
            "val_acc_members": [round(a, 6) for a in res.member_val_acc],
            "val_acc_ensemble": round(res.ensemble_val_acc, 6),
            "test_acc_members": [round(a, 6) for a in res.member_test_acc],
            "test_acc_ensemble": round(res.ensemble_test_acc, 6),
            "weights": wrel,
            "param_names": model.npz_param_names(n_layers),
            "ensemble_hlo": ens_hlo,
            "single_hlo": single_hlo,
        })
        if verbose:
            print(f"  tier {tier.tier}: k={tier.k} hidden={tier.hidden} "
                  f"val_ens={res.ensemble_val_acc:.3f} "
                  f"test_ens={res.ensemble_test_acc:.3f} "
                  f"({time.time() - t0:.1f}s)")

    entry = {
        "name": spec.name,
        "paper_dataset": spec.paper_dataset,
        "classes": spec.classes,
        "dim": spec.dim,
        "n_train": spec.n_train,
        "n_val": spec.n_val,
        "n_test": spec.n_test,
        "data": data_entry,
        "tiers": tiers_entry,
    }
    if verbose:
        print(f"[aot] suite {spec.name} done in {time.time() - t_suite:.1f}s")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output directory")
    ap.add_argument("--suites", default="all",
                    help="comma-separated suite names, or 'all'")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    suites = default_suites()
    if args.suites != "all":
        wanted = set(args.suites.split(","))
        suites = [s for s in suites if s.name in wanted]
        missing = wanted - {s.name for s in suites}
        if missing:
            raise SystemExit(f"unknown suites: {sorted(missing)}")

    t0 = time.time()
    entries = [build_suite(s, out_dir) for s in suites]
    manifest = {
        "format_version": MANIFEST_VERSION,
        "created_unix": int(time.time()),
        "jax_version": jax.__version__,
        "ensemble_buckets": list(ENSEMBLE_BUCKETS),
        "single_buckets": list(SINGLE_BUCKETS),
        "suites": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"[aot] wrote {out_dir / 'manifest.json'} "
          f"({len(entries)} suites, {time.time() - t0:.1f}s total)")


if __name__ == "__main__":
    main()
