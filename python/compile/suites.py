"""Suite and zoo specifications for the ABC reproduction.

Each *suite* is a synthetic stand-in for one of the paper's benchmark
datasets (Table 2).  Each suite carries a *zoo spec*: a ladder of FLOPs
tiers (Figure 1's Pareto ladder), each tier holding an ensemble of ``k``
models trained from different seeds.

The generator (datagen.py) plants a class signal whose energy is spread
uniformly across all ``dim`` features, so a tier that reads only the
first ``input_slice`` dims recovers ``sqrt(input_slice/dim)`` of the
signal -- giving an analytically controlled, *monotone* accuracy ladder.
A per-sample difficulty ``d`` scales the signal (easy samples are
above-average separable, hard ones far below), which is exactly the
structure ABC exploits: small models are right *and agree* on the easy
mass and disagree on the hard tail.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class TierSpec:
    """One cascade tier: an ensemble of ``k`` identical-architecture MLPs."""

    tier: int                 # 1-based tier index (1 = cheapest)
    k: int                    # ensemble size
    hidden: Tuple[int, ...]   # hidden layer widths
    input_slice: int          # number of leading input dims the tier sees
    epochs: int               # training epochs
    train_frac: float = 1.0   # fraction of the training set used


@dataclass(frozen=True)
class SuiteSpec:
    """A synthetic dataset suite plus its model zoo."""

    name: str
    paper_dataset: str        # which paper dataset this stands in for
    classes: int
    dim: int
    n_train: int
    n_val: int
    n_test: int
    seed: int
    # Difficulty distribution Beta(a, b): mass near 0 => mostly-easy suite.
    diff_a: float = 1.2
    diff_b: float = 3.0
    label_noise: float = 0.04  # max label-flip prob (scaled by difficulty^2)
    gain: float = 3.1          # class-signal gain (sets top-tier accuracy)
    sigma: float = 1.0         # isotropic noise std
    d_boost: float = 0.35      # signal boost at difficulty 0
    d_atten: float = 0.55      # signal attenuation at difficulty 1
    tiers: Tuple[TierSpec, ...] = field(default_factory=tuple)


# Batch buckets the runtime AOT-compiles per tier; L3 picks the smallest
# bucket that fits a dynamic batch and pads.
ENSEMBLE_BUCKETS = (1, 8, 32, 128)
SINGLE_BUCKETS = (128,)


def _ladder(k: int, dim: int) -> Tuple[TierSpec, ...]:
    """A 4-tier FLOPs ladder; input slices widen with the tier so accuracy
    is monotone by construction (sqrt(slice/dim) of the signal).

    Slices start at dim/2: the paper's tier-1 models are already decent
    (e.g. 63% ImageNet, ~91% CIFAR-10) -- a too-weak tier 1 makes safe
    deferral select nothing and the cascade degenerates to the top tier.
    """
    s = lambda num, den: max(4, dim * num // den)
    return (
        TierSpec(tier=1, k=k, hidden=(16,), input_slice=s(1, 2), epochs=16,
                 train_frac=0.5),
        TierSpec(tier=2, k=k, hidden=(48,), input_slice=s(2, 3), epochs=20),
        TierSpec(tier=3, k=k, hidden=(128, 64), input_slice=s(5, 6), epochs=24),
        TierSpec(tier=4, k=k, hidden=(320, 160), input_slice=dim, epochs=28),
    )


def default_suites() -> List[SuiteSpec]:
    """The five benchmark suites of DESIGN.md §6 (stand-ins for Table 2)."""
    suites = [
        SuiteSpec(
            name="synth-cifar10", paper_dataset="CIFAR-10",
            classes=10, dim=64, n_train=20000, n_val=4000, n_test=10000,
            seed=101, diff_a=1.1, diff_b=3.4, label_noise=0.05, gain=3.2,
        ),
        SuiteSpec(
            name="synth-imagenet", paper_dataset="ImageNet-1K",
            classes=100, dim=128, n_train=40000, n_val=8000, n_test=10000,
            seed=202, diff_a=1.6, diff_b=2.8, label_noise=0.07, gain=4.8,
        ),
        SuiteSpec(
            name="synth-sst2", paper_dataset="SST-2",
            classes=2, dim=32, n_train=8000, n_val=2000, n_test=872,
            seed=303, diff_a=0.9, diff_b=4.2, label_noise=0.03, gain=2.4,
        ),
        SuiteSpec(
            name="synth-twitterfin", paper_dataset="Twitter Financial News",
            classes=3, dim=32, n_train=6000, n_val=1500, n_test=822,
            seed=404, diff_a=1.4, diff_b=2.8, label_noise=0.06, gain=2.6,
        ),
        SuiteSpec(
            name="synth-swag", paper_dataset="SWAG (MCQ)",
            classes=4, dim=48, n_train=12000, n_val=3000, n_test=4000,
            seed=505, diff_a=1.3, diff_b=2.9, label_noise=0.05, gain=2.9,
        ),
    ]
    out = [
        SuiteSpec(**{**s.__dict__, "tiers": _ladder(3, s.dim)}) for s in suites
    ]
    # Fig. 8 ablation zoo: same CIFAR-10 stand-in data (same seed/geometry)
    # but k=5 members per tier, so ensemble sizes 2..5 can be evaluated by
    # host-side member subsetting.
    cifar = suites[0]
    out.append(SuiteSpec(**{
        **cifar.__dict__,
        "name": "synth-cifar10-k5",
        "tiers": _ladder(5, cifar.dim),
    }))
    return out


def suite_by_name(name: str) -> SuiteSpec:
    for s in default_suites():
        if s.name == name:
            return s
    raise KeyError(f"unknown suite {name!r}")
