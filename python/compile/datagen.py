"""Synthetic classification suites with a controlled difficulty field.

The paper's central phenomenon is that a large fraction of inference data
is 'easy': small models answer it correctly *and agree on it*, while a
hard tail needs the big models (§1, §5).  We reproduce exactly that
statistic, not the pixels of CIFAR-10:

* each class ``c`` owns a random unit direction ``v_c`` in R^dim; the
  class signal is spread uniformly across all dims, so a tier reading the
  first ``m`` dims recovers ``sqrt(m/dim)`` of it -- an analytically
  controlled, monotone accuracy ladder (pairwise class separation
  ``z ~= gain * sqrt(m/dim) * sqrt(2) / (2*sigma)``);
* each sample draws a difficulty ``d ~ Beta(a, b)`` which scales the
  signal: ``s(d) = (1 + d_boost) - (d_boost + d_atten) * d`` -- easy
  samples are extra separable, the hard tail is far below average;
* noise is isotropic ``sigma``; labels flip w.p. ``label_noise * d^2``
  (the paper's label-noise failure mode for confidence cascades, §2.1).

Datasets are written in the ABDS binary format shared with the Rust side
(``rust/src/data/format.rs``):

    magic  b"ABDS"            4 bytes
    version u32 = 1
    n       u32               number of samples
    dim     u32               feature dim
    classes u32
    flags   u32               bit0: has difficulty field
    x       f32[n*dim]        row-major
    y       u32[n]
    diff    f32[n]            iff flags&1

All integers little-endian.
"""

import struct
from pathlib import Path

import numpy as np

from .suites import SuiteSpec

MAGIC = b"ABDS"
VERSION = 1
FLAG_DIFFICULTY = 1


def make_suite_data(spec: SuiteSpec, split: str):
    """Generate one split of a suite. Returns (x, y, difficulty)."""
    n = {"train": spec.n_train, "val": spec.n_val, "test": spec.n_test}[split]
    salt = {"train": 0, "val": 1, "test": 2}[split]
    rng = np.random.default_rng(spec.seed * 1000003 + salt)

    C, D = spec.classes, spec.dim
    # Shared (per-suite, not per-split) geometry: derive from the suite seed.
    geo = np.random.default_rng(spec.seed)
    dirs = geo.standard_normal((C, D)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)

    y = rng.integers(0, C, size=n).astype(np.uint32)
    d = rng.beta(spec.diff_a, spec.diff_b, size=n).astype(np.float32)

    # Per-sample signal scale: easy samples boosted, hard tail attenuated.
    scale = (1.0 + spec.d_boost) - (spec.d_boost + spec.d_atten) * d
    x = dirs[y] * (spec.gain * scale)[:, None]
    x += rng.standard_normal((n, D)).astype(np.float32) * spec.sigma

    # Label noise on the hard tail.
    flip = rng.random(n) < spec.label_noise * d**2
    y_noisy = y.copy()
    y_noisy[flip] = rng.integers(0, C, size=int(flip.sum())).astype(np.uint32)
    return x.astype(np.float32), y_noisy, d


def write_abds(path, x: np.ndarray, y: np.ndarray, diff=None) -> None:
    """Write an ABDS dataset file (see module docstring)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    n, dim = x.shape
    assert y.shape == (n,)
    classes = int(y.max()) + 1 if n else 0
    flags = FLAG_DIFFICULTY if diff is not None else 0
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<IIIII", VERSION, n, dim, classes, flags))
        f.write(np.ascontiguousarray(x, dtype=np.float32).tobytes())
        f.write(np.ascontiguousarray(y, dtype=np.uint32).tobytes())
        if diff is not None:
            assert diff.shape == (n,)
            f.write(np.ascontiguousarray(diff, dtype=np.float32).tobytes())


def read_abds(path):
    """Read an ABDS dataset file. Returns (x, y, diff-or-None)."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        version, n, dim, classes, flags = struct.unpack("<IIIII", f.read(20))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        x = np.frombuffer(f.read(4 * n * dim), dtype=np.float32).reshape(n, dim)
        y = np.frombuffer(f.read(4 * n), dtype=np.uint32)
        diff = None
        if flags & FLAG_DIFFICULTY:
            diff = np.frombuffer(f.read(4 * n), dtype=np.float32)
    return x.copy(), y.copy(), None if diff is None else diff.copy()


def generate_suite(spec: SuiteSpec, out_dir) -> dict:
    """Generate and persist all splits. Returns split -> relative path."""
    out_dir = Path(out_dir)
    rel = {}
    for split in ("train", "val", "test"):
        x, y, d = make_suite_data(spec, split)
        p = out_dir / f"{spec.name}_{split}.abds"
        write_abds(p, x, y, d)
        rel[split] = p.name
    return rel
