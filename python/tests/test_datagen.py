"""Dataset generator + ABDS binary format tests."""

import numpy as np
import pytest

from compile import datagen
from compile.suites import SuiteSpec, default_suites, suite_by_name


def _tiny_spec(**over):
    base = dict(
        name="tiny", paper_dataset="t", classes=4, dim=16,
        n_train=400, n_val=200, n_test=200, seed=7,
    )
    base.update(over)
    return SuiteSpec(**base)


def test_abds_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((37, 5)).astype(np.float32)
    y = rng.integers(0, 3, 37).astype(np.uint32)
    d = rng.random(37).astype(np.float32)
    p = tmp_path / "t.abds"
    datagen.write_abds(p, x, y, d)
    x2, y2, d2 = datagen.read_abds(p)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)
    np.testing.assert_array_equal(d, d2)


def test_abds_no_difficulty(tmp_path):
    x = np.zeros((3, 2), dtype=np.float32)
    y = np.array([0, 1, 0], dtype=np.uint32)
    p = tmp_path / "t.abds"
    datagen.write_abds(p, x, y, None)
    _, _, d = datagen.read_abds(p)
    assert d is None


def test_abds_bad_magic(tmp_path):
    p = tmp_path / "bad.abds"
    p.write_bytes(b"NOPE" + b"\x00" * 40)
    with pytest.raises(ValueError, match="bad magic"):
        datagen.read_abds(p)


def test_generation_deterministic():
    spec = _tiny_spec()
    x1, y1, d1 = datagen.make_suite_data(spec, "train")
    x2, y2, d2 = datagen.make_suite_data(spec, "train")
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    np.testing.assert_array_equal(d1, d2)


def test_splits_differ():
    spec = _tiny_spec(n_val=400, n_test=400)
    xtr, _, _ = datagen.make_suite_data(spec, "train")
    xva, _, _ = datagen.make_suite_data(spec, "val")
    assert not np.allclose(xtr[:100], xva[:100])


def test_shapes_and_ranges():
    spec = _tiny_spec()
    x, y, d = datagen.make_suite_data(spec, "val")
    assert x.shape == (200, 16) and y.shape == (200,) and d.shape == (200,)
    assert y.min() >= 0 and y.max() < 4
    assert d.min() >= 0 and d.max() <= 1
    assert x.dtype == np.float32 and y.dtype == np.uint32


def test_difficulty_monotone_separability():
    """Easy samples must be closer to their class direction than hard ones
    (the structural property ABC exploits)."""
    spec = _tiny_spec(n_train=8000)
    x, y, d = datagen.make_suite_data(spec, "train")
    geo = np.random.default_rng(spec.seed)
    dirs = geo.standard_normal((spec.classes, spec.dim)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    proj = np.einsum("nd,nd->n", x, dirs[y])   # signal projection
    easy = proj[d < 0.2]
    hard = proj[d > 0.6]
    assert easy.mean() > hard.mean() + 0.5


def test_generate_suite_writes_all_splits(tmp_path):
    spec = _tiny_spec()
    rel = datagen.generate_suite(spec, tmp_path)
    assert set(rel) == {"train", "val", "test"}
    for split, name in rel.items():
        x, y, d = datagen.read_abds(tmp_path / name)
        n = {"train": 400, "val": 200, "test": 200}[split]
        assert x.shape == (n, 16) and d is not None


def test_default_suites_consistent():
    suites = default_suites()
    assert len(suites) == 6
    names = {s.name for s in suites}
    assert "synth-cifar10" in names and "synth-imagenet" in names
    assert "synth-cifar10-k5" in names
    k5 = suite_by_name("synth-cifar10-k5")
    assert all(t.k == 5 for t in k5.tiers)
    for s in suites:
        assert len(s.tiers) == 4
        slices = [t.input_slice for t in s.tiers]
        assert slices == sorted(slices), "input slices must be monotone"
        assert s.tiers[-1].input_slice == s.dim
        assert suite_by_name(s.name).name == s.name
    with pytest.raises(KeyError):
        suite_by_name("nope")
