"""Training loop + AOT lowering tests (small configs; the full pipeline
runs at `make artifacts`)."""

import numpy as np
import pytest

from compile import aot, model, train
from compile.datagen import make_suite_data
from compile.suites import SuiteSpec, TierSpec


def _spec():
    return SuiteSpec(
        name="tiny", paper_dataset="t", classes=3, dim=12,
        n_train=2400, n_val=400, n_test=400, seed=11, gain=3.4,
        tiers=(
            # k=3: with k=2 plurality ties are frequent and the
            # low-index tie-break drags the ensemble below its members.
            TierSpec(tier=1, k=3, hidden=(8,), input_slice=6, epochs=10),
            TierSpec(tier=2, k=3, hidden=(16,), input_slice=12, epochs=10),
        ),
    )


@pytest.fixture(scope="module")
def trained():
    spec = _spec()
    tr = make_suite_data(spec, "train")
    va = make_suite_data(spec, "val")
    te = make_suite_data(spec, "test")
    out = {}
    for tier in spec.tiers:
        out[tier.tier] = train.train_tier(
            spec, tier, (tr[0], tr[1]), (va[0], va[1]), (te[0], te[1]))
    return spec, out


def test_training_beats_chance(trained):
    spec, res = trained
    for tier_id, r in res.items():
        assert r.ensemble_val_acc > 1.5 / spec.classes, (
            f"tier {tier_id} barely above chance: {r.ensemble_val_acc}")


def test_ladder_monotone(trained):
    _, res = trained
    assert res[2].ensemble_val_acc >= res[1].ensemble_val_acc - 0.02


def test_ensemble_at_least_mean_member(trained):
    """Majority vote should not be (much) worse than the mean member."""
    _, res = trained
    for r in res.values():
        assert r.ensemble_val_acc >= np.mean(r.member_val_acc) - 0.02


def test_evaluate_counts():
    spec = _spec()
    rng = np.random.default_rng(0)
    params = model.init_params(rng, 2, 6, (8,), 3)
    x = rng.standard_normal((100, 12)).astype(np.float32)
    y = rng.integers(0, 3, 100).astype(np.uint32)
    mv, ev = train.evaluate(params, x, y, input_slice=6)
    assert len(mv) == 2
    assert 0.0 <= ev <= 1.0
    assert all(0.0 <= a <= 1.0 for a in mv)


def test_lower_tier_ensemble_hlo(trained):
    spec, res = trained
    params = res[1].params
    text = aot.lower_tier_ensemble(
        params, input_slice=6, batch=8, dim=spec.dim)
    assert "HloModule" in text
    assert "ENTRY" in text
    # parameter 0 is the input batch; weights follow
    assert "f32[8,12]" in text  # x
    # ENTRY takes x + (w, b) per layer. Nested computations (from the
    # pallas interpret lowering) declare their own parameters, so count
    # only the ENTRY block's.
    entry = text[text.index("ENTRY"):]
    entry = entry[:entry.index("\n}")]
    assert entry.count("parameter(") == 1 + 2 * len(params)
    # output tuple: (maj, frac, score, logits)
    assert "s32[8]" in text


def test_lower_tier_single_hlo(trained):
    spec, res = trained
    params = res[1].params
    text = aot.lower_tier_single(
        params, input_slice=6, batch=4, dim=spec.dim)
    assert "HloModule" in text
    entry = text[text.index("ENTRY"):]
    entry = entry[:entry.index("\n}")]
    assert entry.count("parameter(") == 1 + 2 * len(params)


def test_hlo_has_no_elided_constants(trained):
    """The artifact must be fully parseable: weights are parameters, so no
    large constants may appear elided as 'constant({...})'."""
    spec, res = trained
    text = aot.lower_tier_ensemble(
        res[2].params, input_slice=12, batch=8, dim=spec.dim)
    assert "constant({...})" not in text
