"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.  This is
the CORE correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import agreement, ensemble_linear, ensemble_linear_member
from compile.kernels.ref import (
    agreement_ref,
    ensemble_linear_member_ref,
    ensemble_linear_ref,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype):
    a = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(a, dtype=dtype)


# ---------------------------------------------------------------------------
# ensemble_linear (shared input)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 5),
    b=st.integers(1, 200),
    i=st.integers(1, 96),
    o=st.integers(1, 160),
    act=st.sampled_from(["none", "relu"]),
)
def test_ensemble_linear_matches_ref(k, b, i, o, act):
    rng = np.random.default_rng(k * 1000 + b * 10 + i + o)
    x = _rand(rng, (b, i), jnp.float32)
    w = _rand(rng, (k, i, o), jnp.float32)
    bias = _rand(rng, (k, o), jnp.float32)
    got = ensemble_linear(x, w, bias, activation=act)
    want = ensemble_linear_ref(x, w, bias, activation=act)
    assert got.shape == (k, b, o)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    k=st.integers(1, 4),
    b=st.integers(1, 64),
    i=st.integers(1, 48),
    o=st.integers(1, 64),
)
def test_ensemble_linear_bf16(k, b, i, o):
    """bf16 inputs: accumulate in f32 (preferred_element_type), cast back."""
    rng = np.random.default_rng(7 * k + b + i + o)
    x = _rand(rng, (b, i), jnp.bfloat16)
    w = _rand(rng, (k, i, o), jnp.bfloat16)
    bias = _rand(rng, (k, o), jnp.bfloat16)
    got = ensemble_linear(x, w, bias)
    want = ensemble_linear_ref(x, w, bias)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want, dtype=np.float32),
        rtol=0.06, atol=0.1,
    )


def test_ensemble_linear_block_edges():
    """Batch/output sizes straddling the 128 default block boundary."""
    rng = np.random.default_rng(0)
    for b in (127, 128, 129, 256, 257):
        for o in (127, 128, 129):
            x = _rand(rng, (b, 16), jnp.float32)
            w = _rand(rng, (2, 16, o), jnp.float32)
            bias = _rand(rng, (2, o), jnp.float32)
            got = ensemble_linear(x, w, bias, activation="relu")
            want = ensemble_linear_ref(x, w, bias, activation="relu")
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ensemble_linear_custom_blocks():
    rng = np.random.default_rng(1)
    x = _rand(rng, (70, 24), jnp.float32)
    w = _rand(rng, (3, 24, 40), jnp.float32)
    bias = _rand(rng, (3, 40), jnp.float32)
    got = ensemble_linear(x, w, bias, block_b=32, block_o=16)
    want = ensemble_linear_ref(x, w, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ensemble_linear_shape_errors():
    rng = np.random.default_rng(2)
    x = _rand(rng, (8, 10), jnp.float32)
    w = _rand(rng, (2, 12, 4), jnp.float32)  # I mismatch
    b = _rand(rng, (2, 4), jnp.float32)
    with pytest.raises(ValueError):
        ensemble_linear(x, w, b)
    with pytest.raises(ValueError):
        ensemble_linear_member(x[None], w, b)  # x I-dim mismatch


# ---------------------------------------------------------------------------
# ensemble_linear_member (per-member input)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 5),
    b=st.integers(1, 150),
    i=st.integers(1, 80),
    o=st.integers(1, 140),
    act=st.sampled_from(["none", "relu"]),
)
def test_ensemble_linear_member_matches_ref(k, b, i, o, act):
    rng = np.random.default_rng(k + b * 3 + i * 7 + o * 11)
    x = _rand(rng, (k, b, i), jnp.float32)
    w = _rand(rng, (k, i, o), jnp.float32)
    bias = _rand(rng, (k, o), jnp.float32)
    got = ensemble_linear_member(x, w, bias, activation=act)
    want = ensemble_linear_member_ref(x, w, bias, activation=act)
    assert got.shape == (k, b, o)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_member_variant_consistent_with_shared():
    """Broadcasting shared x to (k, B, I) must give the shared result."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (33, 12), jnp.float32)
    w = _rand(rng, (4, 12, 9), jnp.float32)
    bias = _rand(rng, (4, 9), jnp.float32)
    shared = ensemble_linear(x, w, bias, activation="relu")
    member = ensemble_linear_member(
        jnp.broadcast_to(x, (4, 33, 12)), w, bias, activation="relu")
    np.testing.assert_allclose(shared, member, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# agreement
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    k=st.integers(1, 7),
    b=st.integers(1, 200),
    c=st.integers(2, 64),
)
def test_agreement_matches_ref(k, b, c):
    rng = np.random.default_rng(k * 31 + b * 7 + c)
    lg = _rand(rng, (k, b, c), jnp.float32)
    maj, frac, score = agreement(lg)
    maj_r, frac_r, score_r = agreement_ref(lg)
    np.testing.assert_array_equal(np.asarray(maj), np.asarray(maj_r))
    np.testing.assert_allclose(frac, frac_r, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(score, score_r, rtol=1e-5, atol=1e-6)


def test_agreement_unanimous():
    """All members voting the same class => frac == 1.0, that class wins."""
    k, b, c = 5, 17, 8
    lg = np.full((k, b, c), -5.0, dtype=np.float32)
    lg[:, :, 3] = 5.0
    maj, frac, score = agreement(jnp.asarray(lg))
    assert np.all(np.asarray(maj) == 3)
    np.testing.assert_allclose(np.asarray(frac), 1.0)
    assert np.all(np.asarray(score) > 0.9)


def test_agreement_split_vote_tie_breaks_low():
    """2-2 split between classes 1 and 4 => majority = 1 (lower index)."""
    k, b, c = 4, 6, 5
    lg = np.zeros((k, b, c), dtype=np.float32)
    lg[0, :, 1] = 4.0
    lg[1, :, 1] = 4.0
    lg[2, :, 4] = 4.0
    lg[3, :, 4] = 4.0
    maj, frac, _ = agreement(jnp.asarray(lg))
    assert np.all(np.asarray(maj) == 1)
    np.testing.assert_allclose(np.asarray(frac), 0.5)


def test_agreement_vote_frac_quantised():
    """vote_frac must be a multiple of 1/k."""
    rng = np.random.default_rng(4)
    k = 3
    lg = _rand(rng, (k, 101, 10), jnp.float32)
    _, frac, _ = agreement(lg)
    f = np.asarray(frac) * k
    np.testing.assert_allclose(f, np.round(f), atol=1e-5)


def test_agreement_k1_degenerates_to_argmax():
    rng = np.random.default_rng(5)
    lg = _rand(rng, (1, 50, 12), jnp.float32)
    maj, frac, score = agreement(lg)
    np.testing.assert_array_equal(
        np.asarray(maj), np.asarray(jnp.argmax(lg[0], axis=-1)))
    np.testing.assert_allclose(np.asarray(frac), 1.0)
    probs = np.asarray(jax.nn.softmax(lg[0], axis=-1))
    np.testing.assert_allclose(
        np.asarray(score), probs.max(-1), rtol=1e-5, atol=1e-6)
