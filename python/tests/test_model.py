"""L2 model correctness: kernel-built tier model vs pure-jnp reference."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _mk_params(rng, k, input_slice, hidden, classes):
    return model.init_params(rng, k, input_slice, hidden, classes)


@settings(max_examples=12, deadline=None)
@given(
    k=st.integers(1, 4),
    b=st.integers(1, 64),
    depth=st.integers(1, 3),
    classes=st.integers(2, 12),
)
def test_tier_forward_matches_ref(k, b, depth, classes):
    rng = np.random.default_rng(k * 100 + b + depth * 13 + classes)
    dim, input_slice = 24, 16
    hidden = tuple([20] * (depth - 1) + ([28] if depth >= 1 else []))[:depth]
    hidden = tuple(hidden) if depth > 0 else ()
    params = _mk_params(rng, k, input_slice, hidden, classes)
    x = jnp.asarray(rng.standard_normal((b, dim)).astype(np.float32))
    maj, frac, score, logits = model.tier_forward(
        params, x, input_slice=input_slice)
    maj_r, frac_r, score_r, logits_r = model.tier_forward_ref(
        params, x, input_slice=input_slice)
    np.testing.assert_allclose(logits, logits_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(maj), np.asarray(maj_r))
    np.testing.assert_allclose(frac, frac_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(score, score_r, rtol=1e-4, atol=1e-5)


def test_single_forward_is_member0():
    rng = np.random.default_rng(0)
    params = _mk_params(rng, 3, 12, (16,), 5)
    x = jnp.asarray(rng.standard_normal((40, 20)).astype(np.float32))
    pred, conf, logits = model.single_forward(params, x, input_slice=12)
    ref_logits = model.ensemble_logits_ref(params, x, input_slice=12)[0]
    np.testing.assert_allclose(logits, ref_logits, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(pred), np.asarray(jnp.argmax(ref_logits, axis=-1)))
    probs = np.asarray(jax.nn.softmax(ref_logits, axis=-1))
    np.testing.assert_allclose(np.asarray(conf), probs.max(-1),
                               rtol=1e-5, atol=1e-6)
    assert np.all(np.asarray(conf) >= 1.0 / 5 - 1e-6)
    assert np.all(np.asarray(conf) <= 1.0 + 1e-6)


def test_flops_and_params_closed_form():
    # slice=10, hidden=(20, 30), classes=4
    # layers: 10->20, 20->30, 30->4
    assert model.flops_per_sample(10, (20, 30), 4) == 2 * (200 + 600 + 120)
    assert model.param_count(10, (20, 30), 4) == (200 + 20) + (600 + 30) + (120 + 4)


def test_params_npz_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    params = _mk_params(rng, 2, 8, (6,), 3)
    d = model.params_to_npz_dict(params)
    assert set(d) == {"w0", "b0", "w1", "b1"}
    assert model.npz_param_names(2) == ["w0", "b0", "w1", "b1"]
    p = tmp_path / "w.npz"
    np.savez(p, **d)
    loaded = np.load(p)
    for name in d:
        np.testing.assert_array_equal(loaded[name], d[name])


def test_input_slice_restricts_information():
    """Logits must not depend on features beyond input_slice."""
    rng = np.random.default_rng(2)
    params = _mk_params(rng, 2, 8, (10,), 4)
    x = rng.standard_normal((16, 20)).astype(np.float32)
    x2 = x.copy()
    x2[:, 8:] = 999.0  # mutate ignored dims
    lg1 = model.ensemble_logits(params, jnp.asarray(x), input_slice=8)
    lg2 = model.ensemble_logits(params, jnp.asarray(x2), input_slice=8)
    np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))
