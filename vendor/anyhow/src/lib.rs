//! Vendored zero-dependency subset of the `anyhow` error-handling API.
//!
//! The offline build environment has no crates.io registry, so this crate
//! reimplements exactly the surface the repo uses: `Error`, `Result`,
//! the `anyhow!` / `bail!` / `ensure!` macros, and the `Context`
//! extension trait for `Result` and `Option`.
//!
//! `Error` stores the context chain as a vector of rendered strings
//! (outermost first).  `{e}` displays the outermost message, `{e:#}`
//! joins the whole chain with `": "` -- matching the upstream formatting
//! the codebase relies on for CLI error reporting.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (the new outermost description).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for cause in rest {
                        write!(f, "\n    {cause}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as upstream
// anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    /// Conversion into [`crate::Error`] for both std errors and
    /// already-wrapped `anyhow::Error` values (mirrors upstream's
    /// private `ext::StdError`).
    pub trait IntoError {
        fn into_anyhow(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_anyhow(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_anyhow(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_anyhow().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_anyhow().context(f())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return Err($crate::anyhow!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        return Err($crate::anyhow!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        return Err($crate::anyhow!($err))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $msg:literal $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!($msg));
        }
    };
    ($cond:expr, $fmt:literal, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($fmt, $($arg)*));
        }
    };
    ($cond:expr, $err:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!($err));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: disk on fire");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(5).context("nope").unwrap(), 5);
    }

    #[test]
    fn context_chains_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 3));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 3");
    }

    #[test]
    fn macros_build_errors() {
        fn fail_bail() -> Result<()> {
            bail!("bad value {}", 7);
        }
        fn fail_ensure(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert_eq!(format!("{}", fail_bail().unwrap_err()), "bad value 7");
        assert_eq!(
            format!("{}", fail_ensure(12).unwrap_err()),
            "x too big: 12"
        );
        assert_eq!(fail_ensure(3).unwrap(), 3);
        let from_string = anyhow!(String::from("owned message"));
        assert_eq!(format!("{from_string}"), "owned message");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
