//! Vendored stub of the `xla` crate (xla_extension 0.5.1 bindings).
//!
//! The offline build environment ships neither the crates.io registry nor
//! the XLA C++ runtime, so this crate provides the exact API surface
//! `runtime/engine.rs` and `runtime/executable.rs` use, with two levels of
//! fidelity:
//!
//! * **Host buffers work.** `buffer_from_host_buffer` /
//!   `to_literal_sync` / `Literal::to_vec` round-trip data through host
//!   memory with shape validation, so engine-level unit tests and any code
//!   that only moves tensors still runs.
//! * **Compilation is gated.** `HloModuleProto::from_text_file`,
//!   `compile`, `execute_b` and `read_npz_by_name` return a descriptive
//!   error: executing real AOT artifacts needs the genuine PJRT runtime.
//!   Integration tests already skip when `artifacts/manifest.json` is
//!   absent, and the serving stack can run on the synthetic backend
//!   (`abc_serve::trafficgen::SyntheticClassifier`) instead.
//!
//! Swapping the real bindings back in is a one-line change in the root
//! `Cargo.toml` (point the `xla` dependency at the real crate); no source
//! edits are needed because the signatures match.

use std::fmt;
use std::path::Path;

/// Stub error type (all fallible stub APIs return it).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "vendored xla stub: the PJRT runtime is not \
available in this build; HLO artifacts cannot be compiled or executed \
(use the synthetic serving backend, or link the real xla_extension crate)";

/// Element types a [`Literal`] can hold (the subset the repo uses).
#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Typed host tensor.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<usize>,
}

/// Sealed-ish element trait for [`Literal::to_vec`].
pub trait NativeType: Copy + Sized {
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
    fn wrap(v: Vec<Self>) -> LiteralData;
}

impl NativeType for f32 {
    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            LiteralData::F32(v) => Ok(v.clone()),
            LiteralData::I32(_) => Err(Error::new("literal holds i32, asked for f32")),
        }
    }
    fn wrap(v: Vec<f32>) -> LiteralData {
        LiteralData::F32(v)
    }
}

impl NativeType for i32 {
    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            LiteralData::I32(v) => Ok(v.clone()),
            LiteralData::F32(_) => Err(Error::new("literal holds f32, asked for i32")),
        }
    }
    fn wrap(v: Vec<i32>) -> LiteralData {
        LiteralData::I32(v)
    }
}

impl Literal {
    pub fn from_slice<T: NativeType>(data: &[T], dims: &[usize]) -> Result<Literal> {
        let want: usize = dims.iter().product();
        if want != data.len() {
            return Err(Error::new(format!(
                "shape {:?} needs {} elements, got {}",
                dims,
                want,
                data.len()
            )));
        }
        Ok(Literal { data: T::wrap(data.to_vec()), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Real tuples only come out of executed artifacts, which the stub
    /// cannot produce, so this always errors.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::new(UNAVAILABLE))
    }

    /// Reading `.npz` weight sidecars is part of artifact loading; gated.
    pub fn read_npz_by_name<P: AsRef<Path>, S: AsRef<str>>(
        _path: P,
        _opts: &(),
        _names: &[S],
    ) -> Result<Vec<Literal>> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Marker trait kept for signature compatibility (`use xla::FromRawBytes`).
pub trait FromRawBytes {}

impl FromRawBytes for () {}

/// Parsed HLO module handle (never constructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::new(format!(
            "{UNAVAILABLE}; requested artifact: {}",
            path.as_ref().display()
        )))
    }
}

/// Computation handle wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer (host memory in the stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Loaded executable handle (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// PJRT client over the stub "device" (host memory).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "cpu (vendored stub, no PJRT)".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: Literal::from_slice(data, dims)? })
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: literal.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_buffer_roundtrip() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.device_count() >= 1);
        let b = c.buffer_from_host_buffer(&[1.0f32, 2.0, 3.0, 4.0], &[2, 2], None).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.dims(), &[2, 2]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0f32; 3], &[2, 2], None).is_err());
    }

    #[test]
    fn type_mismatch_rejected() {
        let lit = Literal::from_slice(&[1i32, 2], &[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn compilation_is_gated() {
        assert!(HloModuleProto::from_text_file("/tmp/nope.hlo").is_err());
        let c = PjRtClient::cpu().unwrap();
        let names: Vec<&str> = vec!["w0"];
        assert!(Literal::read_npz_by_name("/tmp/nope.npz", &(), &names).is_err());
        let _ = c; // no executable can exist to call execute_b on
    }
}
